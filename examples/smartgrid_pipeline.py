"""The Smart-Grid Information Integration Pipeline (paper Fig. 3a, §IV.A).

Reproduces the USC campus-microgrid pipeline's structure on the Floe
engine via the Session API: streamed pull ingest (I0/I1), bulk CSV upload
(I6), XML weather fetch (I7), interleaved merge into a parser (I2),
semantic annotation with switch control flow (I3), parallel semantic-DB
inserts (I4/I8), and a progress output pellet (I5).  Declarative
``.elastic`` policies scale pellet cores live against a periodic load
profile (§III, Algorithm 1) — the session manages the controller.

Parse propagates each record's ``kind`` as ``source`` so the I3_annotate
switch routes weather records to the weather port; ``main()`` asserts both
DB branches (meter -> I4, weather -> I8) receive records (regression guard
for the historic wiring bug where weather rows fell through to the meter
branch).

Run:  PYTHONPATH=src python examples/smartgrid_pipeline.py
"""
import threading
import time

from repro import Flow, FnPellet, PullPellet, PushPellet


class StreamIngest(PullPellet):
    """I0/I1: streamed event ingest (pull interface, stateful counter)."""

    def initial_state(self):
        return 0

    def compute(self, messages, emit, state):
        for m in messages:
            if m.is_data():
                state += 1
                emit({"kind": "event", "seq": state, "data": m.payload})
        return state


class Parse(PushPellet):
    """I2: parse events / CSV rows / XML docs into tuples.

    The record's ``kind`` must survive parsing as ``source`` — the
    I3_annotate switch routes on it (weather vs meter).
    """

    def compute(self, rec):
        payload = rec["data"] if isinstance(rec, dict) else rec
        return {"parsed": payload, "source": (rec.get("kind", "bulk")
                                              if isinstance(rec, dict)
                                              else "bulk")}


class Annotate(PushPellet):
    """I3: semantic annotation with switch control flow (meter vs weather)."""
    out_ports = ("meter", "weather")

    def compute(self, rec):
        time.sleep(0.001)  # annotation cost
        if rec["source"] == "weather":
            return {"weather": {**rec, "units": "celsius"}}
        return {"meter": {**rec, "units": "kWh"}}


class TripleInsert(PushPellet):
    """I4/I8: insert semantic triples into the (mock) 4Store DB.

    Each branch gets its own DB table so the pipeline can verify where
    records actually landed.
    """
    dbs = {}
    _lock = threading.Lock()

    def __init__(self, table="default"):
        self.table = table
        with TripleInsert._lock:
            self.db = TripleInsert.dbs.setdefault(table, [])

    def compute(self, rec):
        time.sleep(0.002)  # simulated DB latency
        with TripleInsert._lock:
            self.db.append(rec)
        return len(self.db)


def build() -> Flow:
    flow = Flow("smartgrid")
    meters = flow.pellet("I0_meters", StreamIngest)
    sensors = flow.pellet("I1_sensors", StreamIngest)
    csv = flow.pellet("I6_csv", lambda: FnPellet(
        lambda row: {"kind": "bulk", "data": row}))
    weather = flow.pellet("I7_weather", lambda: FnPellet(
        lambda doc: {"kind": "weather", "data": doc}))
    parse = flow.pellet("I2_parse", Parse, cores=2)
    annotate = flow.pellet("I3_annotate", Annotate, cores=2).elastic(
        max_cores=8, strategy="dynamic", drain_horizon=0.5)
    meter_db = flow.pellet("I4_insert",
                           lambda: TripleInsert("meter"), cores=2).elastic(
        max_cores=8, strategy="dynamic", drain_horizon=0.5)
    weather_db = flow.pellet("I8_insert", lambda: TripleInsert("weather"))
    progress = flow.pellet("I5_progress",
                           lambda: FnPellet(lambda n: f"ingested:{n}"))
    for src in (meters, sensors, csv, weather):
        src >> parse                         # interleaved merge (Fig. 1 P6)
    parse >> annotate
    annotate["meter"].split("round_robin") >> meter_db
    annotate["weather"] >> weather_db
    meter_db >> progress
    weather_db >> progress
    return flow


def main():
    TripleInsert.dbs.clear()
    flow = build()
    with flow.session(sample_interval=0.2) as s:
        t0 = time.time()
        n_weather = 0
        # periodic profile: 1s burst, 1s gap, 3 periods
        for period in range(3):
            for i in range(150):
                s.inject("I0_meters", {"meter": i, "w": period})
                s.inject("I1_sensors", {"sensor": i})
                if i % 10 == 0:
                    s.inject("I7_weather", f"<xml>{i}</xml>")
                    n_weather += 1
                if i % 25 == 0:
                    s.inject("I6_csv", [period, i, 42.0])
                time.sleep(0.004)
            time.sleep(0.5)
        assert s.quiesce(timeout=60)
        stats = s.stats()
        meter_db = TripleInsert.dbs["meter"]
        weather_db = TripleInsert.dbs["weather"]
        # regression: BOTH DB branches received records — weather rows must
        # not fall through to the meter branch (or vanish)
        assert len(weather_db) == n_weather, \
            f"weather branch got {len(weather_db)}/{n_weather} records"
        assert len(meter_db) > 0, "meter branch received no records"
        assert all(r["units"] == "celsius" for r in weather_db)
        assert not s.errors, s.errors[:3]
        print(f"wall time: {time.time()-t0:.1f}s")
        print(f"DB triples: meter={len(meter_db)} weather={len(weather_db)}")
        for name in ("I2_parse", "I3_annotate", "I4_insert"):
            st = stats[name]
            print(f"  {name:13s} processed={st['processed']:4d} "
                  f"cores(final)={st['cores']}")
        scaled = [c for (_, n, _, c) in s.controller.history
                  if n == "I3_annotate"]
        print(f"I3 core allocation over time: min={min(scaled)} "
              f"max={max(scaled)} (dynamic adaptation live)")


if __name__ == "__main__":
    main()
