"""Cluster runtime walkthrough: build -> place -> migrate -> scale out.

A 3-stage flow runs on a simulated-VM cluster (paper §III container model
+ §V adaptation): explicit placement and colocation annotations, a live
flake migration with zero message loss, and strategy-driven VM-level
elasticity — the adaptation controller grants cores on the stage's host
while it can (intra-VM scale-up), then acquires a second VM (paying its
spin-up latency) and live-migrates the hot stage onto it (inter-VM
scale-out), consolidating home and releasing the idle VM when the burst
subsides.

Run:  PYTHONPATH=src python examples/cluster_scaleout.py
"""
import time

from repro import ClusterSpec, Flow, FnPellet


def busy(x):
    time.sleep(0.002)          # a deliberately expensive stage
    return x * 2


def main():
    # -- build + place -----------------------------------------------------
    flow = Flow("cluster-demo")
    source = flow.pellet("source", lambda: FnPellet(lambda x: x,
                                                    sequential=True))
    work = flow.pellet("work", lambda: FnPellet(busy), cores=1)
    sink = flow.pellet("sink", lambda: FnPellet(lambda x: x))
    source >> work >> sink
    source.place(host="h0")
    sink.place(colocate_with=source)       # keep the cheap stages together
    work.elastic(max_cores=8, drain_horizon=0.5)

    # one 4-core VM to start; up to two more may be acquired elastically,
    # each paying 0.3s of spin-up latency before it can host flakes
    spec = ClusterSpec(hosts=1, cores_per_host=4, max_hosts=3,
                       spinup_s=0.3)

    with flow.session(cluster=spec, sample_interval=0.05) as s:
        print("initial placement:", s.describe()["cluster"]["placement"])

        # -- explicit live migration --------------------------------------
        s.inject_many(source, list(range(200)))
        host = s.cluster.acquire_host()            # pays spinup_s
        s.migrate(work, host.name)                 # blocks until ready
        n = len(s.results())
        print(f"after migrate({host.name}): {n}/200 delivered,",
              s.describe()["cluster"]["placement"])
        assert n == 200

        # -- strategy-driven scale-out under a burst -----------------------
        s.inject_many(source, list(range(3000)))
        out = s.results(timeout=120)
        assert len(out) == 3000 and not s.errors
        # let the controller quiesce, consolidate home, release idle VMs
        deadline = time.time() + 10
        while time.time() < deadline and any(
                h["state"] != "released"
                for name, h in s.hosts().items() if name != "h0"):
            time.sleep(0.1)

        d = s.describe()["cluster"]
        print("events:", [e["event"] for e in d["events"]])
        print("final placement:", d["placement"])
        print(f"billable VM time: {d['host_seconds']:.1f}s "
              f"across {len(d['hosts'])} hosts")
        assert [h for h in d["hosts"].values() if h["state"] == "ready"], \
            "the initial fleet stays up"


if __name__ == "__main__":
    main()
