"""Behaviour tests for the Fig. 1 pattern catalogue (P1–P8)."""
import time

import pytest

from repro.core import (Coordinator, Drop, FloeGraph, FnPellet, KeyedEmit,
                        Message, PullPellet, PushPellet, TuplePellet,
                        WindowPellet, stable_hash)
from repro.core.patterns import (BalancedSplit, DirectSplit, DuplicateSplit,
                                 HashSplit, RoundRobinSplit)


def run_graph(graph, inputs, entry, *, landmark_after=False, timeout=30):
    coord = Coordinator(graph).start()
    try:
        for payload in inputs:
            coord.inject(entry, payload)
        if landmark_after:
            coord.inject_landmark(entry)
        assert coord.run_until_quiescent(timeout=timeout), "engine did not quiesce"
        assert not coord.errors, f"pellet errors: {coord.errors}"
        return [m.payload for m in coord.drain_outputs() if m.is_data()]
    finally:
        coord.stop()


# -- P1: push pellet, one compute per message ---------------------------------
def test_push_pellet_p1():
    g = FloeGraph("p1")
    g.add("double", lambda: FnPellet(lambda x: 2 * x))
    out = run_graph(g, [1, 2, 3], "double")
    assert sorted(out) == [2, 4, 6]


# -- P2: pull pellet with stream iterator and state ----------------------------
def test_pull_pellet_p2_running_sum():
    class RunningSum(PullPellet):
        def initial_state(self):
            return 0

        def compute(self, messages, emit, state):
            for m in messages:
                if m.is_data():
                    state += m.payload
                    emit(state)
            return state

    g = FloeGraph("p2")
    g.add("sum", RunningSum)
    out = run_graph(g, [1, 2, 3, 4], "sum")
    assert out == [1, 3, 6, 10]  # sequential => ordered


# -- P3: count window -----------------------------------------------------------
def test_window_pellet_p3():
    class SumWindow(WindowPellet):
        window = 3

        def compute(self, payloads):
            return sum(payloads)

    g = FloeGraph("p3")
    g.add("w", SumWindow)
    out = run_graph(g, [1, 2, 3, 4, 5, 6], "w")
    assert sorted(out) == [6, 15]


def test_window_flush_on_landmark():
    class SumWindow(WindowPellet):
        window = 10  # bigger than input: only the landmark flushes

        def compute(self, payloads):
            return sum(payloads)

    g = FloeGraph("p3b")
    g.add("w", SumWindow)
    out = run_graph(g, [1, 2, 3], "w", landmark_after=True)
    assert out == [6]


# -- P4: cycles / iteration -------------------------------------------------------
def test_cycle_for_loop_p4():
    class CountDown(PushPellet):
        out_ports = ("loop", "done")

        def compute(self, n):
            if n > 0:
                return {"loop": n - 1}
            return {"done": "finished"}

    g = FloeGraph("p4")
    g.add("cd", CountDown)
    g.connect("cd", "cd", src_port="loop", dst_port="in")
    out = run_graph(g, [5], "cd")
    assert out == ["finished"]


# -- P5: synchronous merge (tuple alignment) ---------------------------------------
def test_sync_merge_p5():
    class Join(TuplePellet):
        in_ports = ("a", "b")

        def compute(self, inputs):
            return inputs["a"] + inputs["b"]

    g = FloeGraph("p5")
    # sequential sources: sync merge aligns by arrival order, so in-order
    # delivery is required for a deterministic alignment (paper §II.A)
    g.add("sa", lambda: FnPellet(lambda x: x, sequential=True))
    g.add("sb", lambda: FnPellet(lambda x: x * 10, sequential=True))
    g.add("join", Join)
    g.connect("sa", "join", dst_port="a")
    g.connect("sb", "join", dst_port="b")
    coord = Coordinator(g).start()
    try:
        for i in range(4):
            coord.inject("sa", i)
            coord.inject("sb", i)
        assert coord.run_until_quiescent(timeout=30)
        out = sorted(m.payload for m in coord.drain_outputs())
        assert out == [0, 11, 22, 33]
    finally:
        coord.stop()


# -- P6: interleaved merge -----------------------------------------------------------
def test_interleaved_merge_p6():
    g = FloeGraph("p6")
    g.add("s1", lambda: FnPellet(lambda x: x))
    g.add("s2", lambda: FnPellet(lambda x: x))
    g.add("sink", lambda: FnPellet(lambda x: x))
    g.connect("s1", "sink")
    g.connect("s2", "sink")
    coord = Coordinator(g).start()
    try:
        for i in range(3):
            coord.inject("s1", ("a", i))
            coord.inject("s2", ("b", i))
        assert coord.run_until_quiescent(timeout=30)
        out = coord.drain_outputs()
        assert len(out) == 6
        assert {p[0] for p in (m.payload for m in out)} == {"a", "b"}
    finally:
        coord.stop()


# -- P7: duplicate split ----------------------------------------------------------------
def test_duplicate_split_p7():
    g = FloeGraph("p7")
    g.add("src", lambda: FnPellet(lambda x: x))
    g.add("l", lambda: FnPellet(lambda x: ("l", x)))
    g.add("r", lambda: FnPellet(lambda x: ("r", x)))
    g.connect("src", "l", split="duplicate")
    g.connect("src", "r", split="duplicate")
    out = run_graph(g, [1, 2], "src")
    assert sorted(out) == [("l", 1), ("l", 2), ("r", 1), ("r", 2)]


# -- P8: round-robin split ---------------------------------------------------------------
def test_round_robin_split_p8():
    g = FloeGraph("p8")
    g.add("src", lambda: FnPellet(lambda x: x, sequential=True))
    g.add("l", lambda: FnPellet(lambda x: ("l", x)))
    g.add("r", lambda: FnPellet(lambda x: ("r", x)))
    g.connect("src", "l", split="round_robin")
    g.connect("src", "r", split="round_robin")
    out = run_graph(g, list(range(4)), "src")
    by_sink = {"l": [], "r": []}
    for sink, x in out:
        by_sink[sink].append(x)
    assert len(by_sink["l"]) == 2 and len(by_sink["r"]) == 2


# -- control flow: switch via multi-port + Drop ---------------------------------------------
def test_switch_control_flow():
    class Switch(PushPellet):
        out_ports = ("even", "odd")

        def compute(self, x):
            return {"even": x} if x % 2 == 0 else {"odd": x}

    g = FloeGraph("switch")
    g.add("sw", Switch)
    g.add("se", lambda: FnPellet(lambda x: ("even", x)))
    g.add("so", lambda: FnPellet(lambda x: ("odd", x)))
    g.connect("sw", "se", src_port="even")
    g.connect("sw", "so", src_port="odd")
    out = run_graph(g, [0, 1, 2, 3], "sw")
    assert sorted(out) == [("even", 0), ("even", 2), ("odd", 1), ("odd", 3)]


def test_filter_with_drop():
    g = FloeGraph("filter")
    g.add("f", lambda: FnPellet(lambda x: x if x > 2 else Drop))
    out = run_graph(g, [1, 2, 3, 4], "f")
    assert sorted(out) == [3, 4]


# -- split policy unit behaviour ----------------------------------------------------------------
def test_hash_split_same_key_same_edge():
    s = HashSplit()
    for key in ["alpha", "beta", 42, ("t", 1)]:
        m = Message(payload=0, key=key)
        choices = {tuple(s.choose(m, 5, [0] * 5)) for _ in range(10)}
        assert len(choices) == 1  # deterministic


def test_stable_hash_is_stable():
    assert stable_hash("k1") == stable_hash("k1")
    assert stable_hash(("a", 1)) == stable_hash(("a", 1))


def test_direct_split_addresses_edge():
    s = DirectSplit()
    assert s.choose(Message(payload=0, key=3), 5, [0] * 5) == [3]
    assert s.choose(Message(payload=0, key=7), 5, [0] * 5) == [2]


def test_balanced_split_prefers_short_queue():
    s = BalancedSplit()
    m = Message(payload=0)
    assert s.choose(m, 3, [5, 1, 9]) == [1]


def test_duplicate_and_round_robin_units():
    d = DuplicateSplit()
    assert d.choose(Message(payload=0), 3, [0, 0, 0]) == [0, 1, 2]
    r = RoundRobinSplit()
    seq = [r.choose(Message(payload=0), 3, [0, 0, 0])[0] for _ in range(6)]
    assert seq == [0, 1, 2, 0, 1, 2]


# -- data parallelism ------------------------------------------------------------------------------
def test_data_parallel_instances_complete_out_of_order_ok():
    import random

    def slow_id(x):
        time.sleep(random.uniform(0, 0.01))
        return x

    g = FloeGraph("dp")
    g.add("p", lambda: FnPellet(slow_id), cores=4)
    out = run_graph(g, list(range(32)), "p")
    assert sorted(out) == list(range(32))  # all arrive, any order


def test_sequential_pellet_preserves_order():
    class Seq(PushPellet):
        sequential = True

        def compute(self, x):
            time.sleep(0.001)
            return x

    g = FloeGraph("seq")
    g.add("p", Seq)
    out = run_graph(g, list(range(16)), "p")
    assert out == list(range(16))
