"""floe-lint: the static-analysis plane.

Each rule is proven live against an intentionally-broken fixture, the
clean fixture passes every analyzer, waiver mechanics round-trip, and —
the actual point — the engine source itself is strict-clean under the
repo waiver file.
"""
import json
import os

import pytest

from repro.analysis import (Finding, RULES, analyze_guards,
                            analyze_lock_order, analyze_pellets, apply_waivers,
                            gating, lint_example_file, load_waivers, run)
from repro.analysis.cli import main as cli_main
from repro.analysis.waivers import Waiver, WaiverError

HERE = os.path.dirname(os.path.abspath(__file__))
FIX = os.path.join(HERE, "fixtures", "analysis")
REPO = os.path.dirname(HERE)
SRC = os.path.join(REPO, "src", "repro")
WAIVERS = os.path.join(REPO, "analysis", "waivers.toml")


def rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# lock-order analyzer
# ---------------------------------------------------------------------------

class TestLockOrder:
    def test_cycle_detected(self):
        fs = analyze_lock_order([os.path.join(FIX, "deadlock_cycle.py")])
        cycles = [f for f in fs if f.rule == "FL001"]
        assert cycles, "opposite-order acquisition must raise FL001"
        assert cycles[0].severity == "error"
        assert "Ledger._book_lock" in cycles[0].symbol
        assert "Ledger._audit_lock" in cycles[0].symbol

    def test_self_deadlock_and_notes(self):
        fs = analyze_lock_order([os.path.join(FIX, "deadlock_cycle.py")])
        assert {"FL001", "FL002", "FL003", "FL004"} <= rules_of(fs)

    def test_clean_module_passes(self):
        assert analyze_lock_order([os.path.join(FIX, "clean_module.py")]) == []

    def test_engine_lock_hierarchy_is_acyclic(self):
        fs = analyze_lock_order([SRC])
        assert [f for f in fs if f.rule in ("FL001", "FL002")] == []


# ---------------------------------------------------------------------------
# guarded-by checker
# ---------------------------------------------------------------------------

class TestGuardedBy:
    def test_violations_fire(self):
        fs = analyze_guards([os.path.join(FIX, "guarded_violation.py")])
        assert {"FL101", "FL102", "FL103"} == rules_of(fs)
        racy = [f for f in fs if "racy_read" in f.symbol]
        assert racy and racy[0].severity == "error"

    def test_condition_alias_counts_as_lock(self):
        fs = analyze_guards([os.path.join(FIX, "guarded_violation.py")])
        assert not any("bump_via_cond" in f.symbol for f in fs), \
            "a Condition wrapping the guard lock must satisfy guarded-by"

    def test_cross_object_access_checked(self):
        fs = analyze_guards([os.path.join(FIX, "guarded_violation.py")])
        assert any(f.symbol == "Counter._n@poke" for f in fs)

    def test_clean_module_passes(self):
        assert analyze_guards([os.path.join(FIX, "clean_module.py")]) == []

    def test_engine_annotations_hold_modulo_waivers(self):
        fs = analyze_guards([SRC])
        kept, waived = apply_waivers(fs, load_waivers(WAIVERS))
        assert [f for f in kept if f.rule.startswith("FL1")] == [], \
            "every guarded-by finding on src/repro is fixed or waived"
        assert waived, "the repo waiver file documents the deliberate reads"


# ---------------------------------------------------------------------------
# pellet-contract checker
# ---------------------------------------------------------------------------

class TestPelletContracts:
    def test_each_rule_fires(self):
        fs = analyze_pellets([os.path.join(FIX, "bad_pellet.py")])
        assert {"FL301", "FL302", "FL303", "FL304",
                "FL305"} == rules_of(fs)

    def test_clean_module_passes(self):
        assert analyze_pellets([os.path.join(FIX, "clean_module.py")]) == []

    def test_engine_pellets_pass(self):
        assert analyze_pellets([SRC]) == []


# ---------------------------------------------------------------------------
# dataflow linter — static front-end (examples idiom)
# ---------------------------------------------------------------------------

class TestStaticFlowLint:
    def test_wedge_fixture(self):
        fs = lint_example_file(os.path.join(FIX, "wedge_flow.py"))
        assert {"FL201", "FL203", "FL204"} == rules_of(fs)
        wedge = [f for f in fs if f.rule == "FL203"]
        assert "join" in wedge[0].message and "back-edge" in wedge[0].message

    def test_examples_extract_without_fabrication(self):
        # the shipped examples lint without errors; the extractor may
        # mark loop-built flows incomplete but must not invent findings
        for name in sorted(os.listdir(os.path.join(REPO, "examples"))):
            if not name.endswith(".py"):
                continue
            fs = lint_example_file(os.path.join(REPO, "examples", name))
            assert gating(fs) == [], (name, [f.format() for f in fs])


# ---------------------------------------------------------------------------
# dataflow linter — runtime front-end (Flow.lint)
# ---------------------------------------------------------------------------

class TestFlowLint:
    def _hazard_flow(self):
        from repro.api.builder import Flow
        from repro.core.pellet import FnPellet
        f = Flow("hazards")
        src = f.pellet("src", lambda: FnPellet(lambda x: x))
        a = f.pellet("a", lambda: FnPellet(lambda x: x))
        b = f.pellet("b", lambda: FnPellet(lambda x: x))
        snk = f.sink("snk", None, exactly_once=True)
        src >> a
        a >> b
        b >> a
        a >> snk
        return f

    def test_wedge_and_unkeyed_sink(self):
        fs = self._hazard_flow().lint()
        assert {"FL203", "FL204"} <= rules_of(fs)

    def test_exactly_once_with_key_is_clean(self):
        from repro.api.builder import Flow
        from repro.core.pellet import FnPellet
        f = Flow("keyed")
        src = f.pellet("src", lambda: FnPellet(lambda x: x))
        a = f.pellet("a", lambda: FnPellet(lambda x: x))
        b = f.pellet("b", lambda: FnPellet(lambda x: x))
        snk = f.sink("snk", None, exactly_once=True, key=lambda p: p["rid"])
        src >> a
        a >> b
        b >> a
        a >> snk
        assert not any(x.rule == "FL204" for x in f.lint())

    def test_array_optin_without_capability(self):
        from repro.api.builder import Flow
        from repro.core.pellet import FnPellet
        f = Flow("arr")
        s = f.pellet("s", lambda: FnPellet(lambda x: x))
        s.batch(8, array=True)          # row-wise fn: cannot consume arrays
        assert any(x.rule == "FL205" for x in f.lint())
        f2 = Flow("arr2")
        s2 = f2.pellet("s2", lambda: FnPellet(lambda xs: xs, vectorized=True))
        s2.batch(8, array=True)
        assert not any(x.rule == "FL205" for x in f2.lint())

    def test_nested_pytree_sample_degrades(self):
        import numpy as np
        from repro.api.builder import Flow
        from repro.core.pellet import FnPellet
        f = Flow("pytree")
        s = f.pellet("s", lambda: FnPellet(lambda xs: xs, vectorized=True))
        s.batch(8, array=True)
        nested = {"v": {"inner": 1.0}}
        flat = {"v": np.ones(4), "w": 2.0}
        assert any(x.rule == "FL206"
                   for x in f.lint(samples={"s": nested}))
        assert not any(x.rule == "FL206"
                       for x in f.lint(samples={"s": flat}))

    def test_unpicklable_named_factory_noted(self):
        import functools
        import threading
        from repro.api.builder import Flow
        from repro.core.pellet import FnPellet

        f = Flow("offload")
        # a named, partial-bound factory closing over a lock: looks
        # offloadable, is not — unlike the idiomatic lambdas, which pass
        f.pellet("s", functools.partial(_make_pellet, threading.Lock()))
        assert any(x.rule == "FL207" for x in f.lint())
        f2 = Flow("offload2")
        f2.pellet("s", lambda: FnPellet(lambda x: x))
        assert not any(x.rule == "FL207" for x in f2.lint())

    def test_clean_pipeline_lints_empty(self):
        from repro.api.builder import Flow
        from repro.core.pellet import FnPellet
        f = Flow("clean")
        a = f.pellet("a", lambda: FnPellet(lambda x: x))
        b = f.pellet("b", lambda: FnPellet(lambda x: x))
        a >> b
        assert f.lint() == []


def _make_pellet(lock):
    from repro.core.pellet import FnPellet
    return FnPellet(lambda x: x)


# ---------------------------------------------------------------------------
# waivers
# ---------------------------------------------------------------------------

class TestWaivers:
    def test_waiver_filters_and_stale_reports(self):
        f1 = Finding("FL101", "error", "x.py", 1, "msg", symbol="A.b@A.c")
        f2 = Finding("FL101", "error", "y.py", 2, "msg", symbol="D.e@D.f")
        ws = [Waiver("FL101", "A.b@A.c", "reviewed"),
              Waiver("FL001", "never-matches", "stale entry")]
        kept, waived = apply_waivers([f1, f2], ws)
        assert [f.symbol for f, _ in waived] == ["A.b@A.c"]
        assert {f.rule for f in kept} == {"FL101", "FL901"}
        assert any(f.rule == "FL901" and "never-matches" in f.message
                   for f in kept)

    def test_waiver_requires_reason(self, tmp_path):
        p = tmp_path / "w.toml"
        p.write_text('[[waiver]]\nrule = "FL101"\nmatch = "x"\n')
        with pytest.raises(WaiverError):
            load_waivers(str(p))

    def test_repo_waiver_file_has_no_stale_entries(self):
        kept, waived = run([SRC], WAIVERS)
        assert not any(f.rule == "FL901" for f in kept), \
            [f.message for f in kept if f.rule == "FL901"]


# ---------------------------------------------------------------------------
# the gate: src/repro is strict-clean, and the CLI enforces it
# ---------------------------------------------------------------------------

class TestGate:
    def test_src_repro_strict_clean(self):
        kept, _ = run([SRC], WAIVERS)
        assert gating(kept) == [], "\n".join(f.format() for f in kept)

    def test_cli_strict_exit_codes(self, capsys):
        rc = cli_main([SRC, "--strict", "--waivers", WAIVERS])
        assert rc == 0
        rc = cli_main([os.path.join(FIX, "deadlock_cycle.py"),
                       "--strict", "--waivers", "none"])
        assert rc == 1
        out = capsys.readouterr().out
        assert "FL001" in out

    def test_cli_json_format(self, capsys):
        rc = cli_main([os.path.join(FIX, "bad_pellet.py"),
                       "--format", "json", "--waivers", "none"])
        assert rc == 0                      # non-strict: report, don't gate
        data = json.loads(capsys.readouterr().out)
        assert {d["rule"] for d in data} >= {"FL301", "FL303"}
        assert all({"rule", "severity", "file", "line", "message"}
                   <= set(d) for d in data)

    def test_cli_rules_catalogue(self, capsys):
        assert cli_main(["--rules"]) == 0
        out = capsys.readouterr().out
        for rule in RULES:
            assert rule in out

    def test_cli_skips_fixture_dirs_unless_rooted(self, capsys):
        rc = cli_main([HERE, "--waivers", "none"])
        assert rc == 0
        out = capsys.readouterr().out
        assert "FL001" not in out, \
            "fixtures must not leak into a plain tests/ sweep"

    def test_parse_failure_is_a_finding(self, tmp_path):
        bad = tmp_path / "broken.py"
        bad.write_text("def oops(:\n")
        kept, _ = run([str(tmp_path)], None)
        assert [f.rule for f in kept] == ["FL000"]
