"""Checkpoint/restart fault tolerance: train state, async snapshots, Floe
graph state + pending-message replay, elastic resume."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, checkpoint_floe_graph,
                              restore, restore_floe_graph, save)
from repro.configs import registry
from repro.data import TokenPipeline
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.optim import init_state


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get("smollm-360m").scaled_down()
    step, model = make_train_step(cfg)
    jstep = jax.jit(step)
    pipe = TokenPipeline(cfg, global_batch=4, seq_len=16, seed=3)
    return cfg, model, jstep, pipe


def tree_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(fa, fb))


def test_save_restore_roundtrip(tmp_path, setup):
    cfg, model, jstep, pipe = setup
    state = init_state(model.init(jax.random.PRNGKey(0)))
    state, _ = jstep(state, pipe.batch_at(0))
    path = str(tmp_path / "ckpt")
    save(path, state, step=1)
    back = restore(path, like=state)
    assert tree_equal(state, back)


def test_restart_resumes_identical_training(tmp_path, setup):
    """Kill-and-restart equivalence: train 4 steps straight vs train 2,
    checkpoint, 'crash', restore, train 2 more — identical final state
    (deterministic data pipeline + saved optimizer state)."""
    cfg, model, jstep, pipe = setup
    s = init_state(model.init(jax.random.PRNGKey(0)))
    for i in range(4):
        s, _ = jstep(s, pipe.batch_at(i))
    straight = s

    s2 = init_state(model.init(jax.random.PRNGKey(0)))
    for i in range(2):
        s2, _ = jstep(s2, pipe.batch_at(i))
    save(str(tmp_path / "c2"), s2, step=2)
    del s2                                            # "crash"
    s3 = restore(str(tmp_path / "c2"), like=straight)
    for i in range(2, 4):
        s3, _ = jstep(s3, pipe.batch_at(i))
    assert tree_equal(straight, s3)


def test_async_checkpointer_retention(tmp_path, setup):
    cfg, model, jstep, pipe = setup
    state = init_state(model.init(jax.random.PRNGKey(0)))
    ck = AsyncCheckpointer(str(tmp_path / "root"), keep=2)
    for i in (1, 2, 3):
        ck.save_async(i, state)
    ck.wait()
    names = sorted(os.listdir(str(tmp_path / "root")))
    assert names == ["step_2", "step_3"]              # retention
    step, back = ck.restore_latest(like=state)
    assert step == 3 and tree_equal(state, back)


def test_floe_graph_checkpoint_replays_pending(tmp_path):
    from repro.core import Coordinator, FloeGraph, FnPellet, PullPellet

    class Summer(PullPellet):
        def initial_state(self):
            return 0

        def compute(self, messages, emit, state):
            for m in messages:
                if m.is_data():
                    state += m.payload
                    emit(state)
            return state

    g = FloeGraph("ck")
    g.add("sum", Summer)
    coord = Coordinator(g).start()
    try:
        coord.inject("sum", 10)
        coord.inject("sum", 5)
        assert coord.run_until_quiescent(timeout=30)
        # park two messages (pause = simulate failure with queued input)
        coord.flakes["sum"].pause()
        coord.inject("sum", 7)
        coord.inject("sum", 3)
        time.sleep(0.1)
        path = str(tmp_path / "floe.pkl")
        checkpoint_floe_graph(coord, path)
    finally:
        coord.stop()
    # "restart": a fresh engine restores state + replays pending messages
    g2 = FloeGraph("ck")
    g2.add("sum", Summer)
    coord2 = Coordinator(g2).start()
    try:
        restore_floe_graph(coord2, path)
        assert coord2.run_until_quiescent(timeout=30)
        assert coord2.flakes["sum"].state == 25       # 15 restored + 7 + 3
        out = [m.payload for m in coord2.drain_outputs()]
        assert sorted(out) == [22, 25]                # replayed execution
    finally:
        coord2.stop()


def test_elastic_resume_smaller_mesh(tmp_path, setup):
    """Node-failure handling: restore the same checkpoint into a training
    run configured for fewer replicas (divisor resize) — state restores and
    training proceeds (single-device stand-in for the re-mesh)."""
    cfg, model, jstep, pipe = setup
    s = init_state(model.init(jax.random.PRNGKey(0)))
    s, _ = jstep(s, pipe.batch_at(0))
    save(str(tmp_path / "c"), s, step=1)
    restored = restore(str(tmp_path / "c"), like=s)
    # half the replicas -> half the global batch, same step function
    small_pipe = TokenPipeline(cfg, global_batch=2, seq_len=16, seed=3)
    s2, metrics = jstep(restored, small_pipe.batch_at(1))
    assert np.isfinite(float(metrics["loss"]))


def test_checkpoint_captures_push_pellet_instance_state(tmp_path):
    """ROADMAP follow-up: mutable state a push pellet keeps on ``self``
    (outside the explicit state object) survives checkpoint/restore via
    the ``__floe_state__``/get_state hook."""
    from repro.api import Flow, Session
    from repro.core import PushPellet

    class Dedup(PushPellet):
        """Drops repeats — the seen-set is instance state."""
        __floe_state__ = ("seen",)
        sequential = True

        def __init__(self):
            self.seen = set()

        def compute(self, x):
            if x in self.seen:
                from repro.core import Drop
                return Drop
            self.seen.add(x)
            return x

    flow = Flow("ps")
    flow.pellet("d", Dedup)
    path = str(tmp_path / "floe.ckpt")
    with flow.session() as s:
        s.inject_many("d", [1, 2, 3, 2])
        assert sorted(s.results()) == [1, 2, 3]
        s.checkpoint(path)
    # restart: the fresh pellet instance must remember what it has seen
    with Session.restore(path, flow) as s2:
        proto = s2.coordinator.flakes["d"]._proto
        assert proto.seen == {1, 2, 3}
        s2.inject_many("d", [3, 4])
        assert s2.results() == [4]          # 3 still deduped post-restore


def test_checkpoint_custom_get_state_override(tmp_path):
    """Pellets can override get_state/set_state directly (no attr list)."""
    from repro.api import Flow, Session
    from repro.core import PushPellet

    class Counter(PushPellet):
        sequential = True

        def __init__(self):
            self.count = 0

        def compute(self, x):
            self.count += 1
            return (self.count, x)

        def get_state(self):
            return self.count

        def set_state(self, snapshot):
            self.count = snapshot

    flow = Flow("cnt")
    flow.pellet("c", Counter)
    path = str(tmp_path / "floe.ckpt")
    with flow.session() as s:
        s.inject_many("c", ["a", "b"])
        assert sorted(s.results()) == [(1, "a"), (2, "b")]
        s.checkpoint(path)
    with Session.restore(path, flow) as s2:
        s2.inject("c", "c")
        assert s2.results() == [(3, "c")]   # numbering continues


# -- atomic write + corruption detection --------------------------------------

def _simple_flow():
    from repro.api import Flow
    from repro.core import FnPellet
    flow = Flow("atomic")
    flow.pellet("id", lambda: FnPellet(lambda x: x))
    return flow


def test_checkpoint_write_is_atomic_no_tmp_left(tmp_path):
    flow = _simple_flow()
    path = str(tmp_path / "cut.floe")
    with flow.session() as s:
        s.inject("id", 1)
        s.results()
        s.checkpoint(path)
    assert os.path.exists(path)
    # the temp file used for the atomic rename must not survive
    assert [n for n in os.listdir(tmp_path) if n.endswith(".tmp")] == []


def test_restore_truncated_checkpoint_raises(tmp_path):
    """Regression: a checkpoint truncated mid-write (crash during save
    before atomic rename existed) must fail loudly, not unpickle garbage
    or silently restore a partial graph."""
    from repro.api import Session
    from repro.checkpoint import CheckpointCorruptError

    flow = _simple_flow()
    path = str(tmp_path / "cut.floe")
    with flow.session() as s:
        s.inject_many("id", list(range(100)))
        s.results()
        s.checkpoint(path)
    data = open(path, "rb").read()
    for cut in (len(data) // 2, 10, 3):     # payload, header, magic
        open(path, "wb").write(data[:cut])
        with pytest.raises(CheckpointCorruptError):
            Session.restore(path, _simple_flow())


def test_restore_corrupted_byte_fails_checksum(tmp_path):
    from repro.api import Session
    from repro.checkpoint import CheckpointCorruptError

    flow = _simple_flow()
    path = str(tmp_path / "cut.floe")
    with flow.session() as s:
        s.checkpoint(path)
    data = bytearray(open(path, "rb").read())
    data[-1] ^= 0xFF                        # flip one payload byte
    open(path, "wb").write(bytes(data))
    with pytest.raises(CheckpointCorruptError):
        Session.restore(path, _simple_flow())


def test_restore_reads_legacy_raw_pickle(tmp_path):
    """Pre-manifest checkpoints (raw pickle, no FLOECKPT header) still
    restore."""
    import pickle

    from repro.checkpoint import read_floe_meta
    from repro.checkpoint.checkpointer import _read_floe_state

    flow = _simple_flow()
    path = str(tmp_path / "cut.floe")
    with flow.session() as s:
        s.checkpoint(path)
    state = _read_floe_state(path)
    legacy = str(tmp_path / "legacy.pkl")
    with open(legacy, "wb") as f:
        pickle.dump(state, f)
    assert read_floe_meta(legacy)["flow"] == read_floe_meta(path)["flow"]
