"""Process-backed worker hosts: real OS processes behind the cluster's
``Host`` objects, a pickle-5 control channel, and zero-copy ArrayBatch
transfer through shared-memory rings.

The load-bearing assertions:

* remote execution is REAL — a pellet observing ``os.getpid()`` sees the
  worker's pid, not the parent's;
* an ArrayBatch crossing a process-host edge pickles no array bytes
  (transport ledger: ``bytes == 0``, ``control_bytes > 0``,
  ``shm_bytes > 0``);
* ``backend="sim"`` (the default) is byte-for-byte unchanged — no worker,
  no remote runner;
* a killed worker process fails real liveness pings, so the fault plane's
  detection → ``host_failed`` → recovery arc works unmodified.

Every pellet function here is module-level: spawn workers re-import this
module to unpickle the shipped factories.
"""
import functools
import os
import time

import numpy as np
import pytest

from repro import ClusterError, ClusterSpec, Flow, FnPellet, RecoveryPolicy
from repro.cluster.backends import SimBackend, make_backend
from repro.cluster.manager import ClusterManager
from repro.cluster.workers import ProcessBackend, ShmRing
from repro.faults import CheckpointPolicy

from conftest import wait_until


# -- module-level pellet functions (spawn workers unpickle by reference) ----

def _double(x):
    return x * 2


def _plus_tag(x):
    return x + 1000


def _pid_of(x):
    return float(os.getpid())


def _vec(X):
    return X * 2.0 + 1.0


def _make_double():
    return FnPellet(_double)


def _make_plus():
    return FnPellet(_plus_tag)


def _make_pid():
    return FnPellet(_pid_of)


def _make_vec():
    return FnPellet(_vec, vectorized=True)


def _proc_spec(hosts=2, **kw):
    kw.setdefault("cores_per_host", 4)
    kw.setdefault("placement", "spread")
    return ClusterSpec(hosts=hosts, backend="process", **kw)


# -- spec / backend plumbing -------------------------------------------------

def test_spec_backend_validation():
    with pytest.raises(ClusterError):
        ClusterSpec(backend="nope")
    with pytest.raises(ClusterError):
        ClusterSpec(backend="process", shm_ring_bytes=16)
    # a process backend on the loopback default upgrades the transport so
    # cross-host edges get real (counted) serialization semantics
    assert ClusterSpec(backend="process").transport == "process"
    # the process wire needs a process on the other end
    with pytest.raises(ClusterError):
        ClusterSpec(backend="sim", transport="process")
    # explicit serializing transport is allowed with process hosts
    assert ClusterSpec(backend="process",
                       transport="serializing").backend == "process"


def test_make_backend_dispatch():
    assert isinstance(make_backend(ClusterSpec()), SimBackend)
    spec = ClusterSpec(backend="process")
    b = make_backend(spec)
    assert isinstance(b, ProcessBackend) and b.blocking_spinup
    b.shutdown()


def test_sim_default_unchanged():
    """No backend= → SimBackend: no workers, no remote runners."""
    flow = Flow("sim")
    a = flow.pellet("a", _make_double)
    with flow.session(cluster=ClusterSpec(hosts=2)) as s:
        mgr = s.coordinator.cluster
        assert isinstance(mgr.backend, SimBackend)
        assert all(h.worker is None for h in mgr.hosts.values())
        s.inject_many("a", [1, 2, 3])
        assert sorted(s.results(10)) == [2, 4, 6]
        assert all(f.remote is None
                   for f in s.coordinator.flakes.values())


# -- shm ring mechanics ------------------------------------------------------

def test_shm_ring_pack_and_map():
    ring = ShmRing(1 << 16)
    try:
        a = np.arange(12.0).reshape(3, 4)
        b = np.arange(5, dtype=np.int64)
        specs = ring.write([a, b])
        assert [s[2] for s in specs] == [0, a.nbytes]
        va = ring.view(specs[0])
        assert not va.flags.writeable            # zero-copy view
        np.testing.assert_array_equal(va, a)
        owned = ring.read(specs[1])
        np.testing.assert_array_equal(owned, b)
        assert owned.flags.writeable             # result copies are owned
        assert not ring.fits([np.zeros(1 << 14)])
        with pytest.raises(ValueError):
            ring.write([np.zeros(1 << 14)])
    finally:
        ring.close()


# -- end-to-end process compute ---------------------------------------------

@pytest.mark.timeout(120)
def test_process_chain_remote_execution():
    """Results are correct AND provably computed in the worker process."""
    flow = Flow("proc")
    a = flow.pellet("a", _make_double)
    b = flow.pellet("b", _make_double)
    a >> b
    with flow.session(cluster=_proc_spec(hosts=2)) as s:
        mgr = s.coordinator.cluster
        assert isinstance(mgr.backend, ProcessBackend)
        for h in mgr.hosts.values():
            assert h.worker is not None and h.worker.alive()
            assert h.worker.pid != os.getpid()
        s.inject_many("a", list(range(20)))
        assert sorted(s.results(30)) == [i * 4 for i in range(20)]
        d = mgr.describe()
        assert d["backend"]["backend"] == "process"
        assert d["transport"]["kind"] == "process"
        assert d["transport"]["messages"] > 0

    flow2 = Flow("pid")
    p = flow2.pellet("p", _make_pid)
    with flow2.session(cluster=_proc_spec(hosts=1)) as s:
        s.inject_many("p", [0, 1, 2])
        pids = {int(x) for x in s.results(30)}
        assert pids and all(pid != os.getpid() for pid in pids)


@pytest.mark.timeout(120)
def test_zero_copy_array_ledger():
    """The acceptance property: a vectorized chain on process hosts moves
    every array through the shm rings — the pickled-payload ledger stays
    at zero while control traffic and shm traffic are both nonzero."""
    flow = Flow("zc")
    a = flow.pellet("a", _make_vec).batch(64, array=True)
    b = flow.pellet("b", _make_vec).batch(64, array=True)
    a >> b
    with flow.session(cluster=_proc_spec(hosts=2)) as s:
        s.inject_many("a", [np.full(256, float(i)) for i in range(64)],
                      stacked=True)
        out = s.results(30)
        assert len(out) == 64
        got = sorted(float(np.asarray(r)[0]) for r in out)
        want = sorted(float(i) * 4.0 + 3.0 for i in range(64))
        np.testing.assert_allclose(got, want)
        st = s.coordinator.cluster.transport.stats
        assert st.bytes == 0, \
            f"array bytes were pickled: {st.describe()}"
        assert st.shm_bytes > 0 and st.control_bytes > 0


@pytest.mark.timeout(120)
def test_non_picklable_factory_falls_back_local():
    """A lambda factory can't cross the process boundary: the flake
    silently degrades to parent-local compute (counted), results exact."""
    flow = Flow("fb")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x * 3))
    with flow.session(cluster=_proc_spec(hosts=1)) as s:
        s.inject_many("a", [1, 2, 3, 4])
        assert sorted(s.results(20)) == [3, 6, 9, 12]
        host = next(iter(s.coordinator.cluster.hosts.values()))
        assert host.worker.fallbacks >= 1
        assert host.worker.describe()["fallbacks"] >= 1


@pytest.mark.timeout(120)
def test_stateful_pellet_computes_in_parent():
    """Stateful pellets keep state where checkpoints live (the parent),
    regardless of process placement — offload eligibility excludes them."""
    flow = Flow("st")
    a = flow.pellet("a", _make_pid)
    with flow.session(cluster=_proc_spec(hosts=1)) as s:
        flake = s.coordinator.flakes["a"]
        assert flake.remote is not None

        class _Stateful:
            stateful = True
        assert not flake._remote_eligible(_Stateful())


@pytest.mark.timeout(180)
def test_worker_kill_is_host_failure_and_recovers():
    """SIGKILL the worker behind h1: Host.ping() now reports real process
    liveness, so the unmodified fault plane detects it, emits
    ``host_failed``, and recovery re-places the flake on the survivor —
    where it keeps computing (remotely, on the survivor's live worker)."""
    flow = Flow("rec")
    src = flow.pellet("src", _make_double).place(host="h0")
    mid = flow.pellet("mid", _make_plus).place(host="h1")
    src >> mid
    pol = RecoveryPolicy(
        checkpoint=CheckpointPolicy(interval_s=0.25, freeze_timeout_s=10.0),
        heartbeat_interval_s=0.05, suspicion_timeout_s=0.2,
        max_row_retries=4, restart_backoff_s=0.01)
    with flow.session(cluster=_proc_spec(hosts=2), recovery=pol) as s:
        s.inject_many("src", list(range(50)))
        s.results(timeout=30)

        victim = s.cluster.hosts["h1"].worker
        victim.kill()                      # real SIGKILL, no bookkeeping
        assert wait_until(lambda: not victim.alive(), timeout=10)
        assert wait_until(lambda: s.faults.recoveries, timeout=30), \
            "worker death was never detected/recovered"
        rec = s.faults.last_recovery
        assert rec["host"] == "h1" and "mid" in rec["flakes"]
        assert rec["placed"]["mid"] != "h1"
        assert any(e["kind"] == "host_failed" for e in s.events())

        # post-recovery wave: flows end-to-end on the surviving host
        wave2 = list(range(1000, 1040))
        s.inject_many("src", wave2)
        expect = {i * 2 + 1000 for i in wave2}
        got = set()

        def _drain():
            got.update(s.results(timeout=2))
            return expect <= got
        assert wait_until(_drain, timeout=60), \
            f"missing {sorted(expect - got)[:5]}"
        surv = s.cluster.hosts["h0"].worker
        assert surv is not None and surv.alive()


@pytest.mark.timeout(120)
def test_backend_shutdown_reaps_workers():
    mgr = ClusterManager(_proc_spec(hosts=2))
    workers = [h.worker for h in mgr.hosts.values()]
    assert all(w is not None for w in workers)
    for w in workers:
        w.wait_ready(60)
    pids = [w.pid for w in workers]
    mgr.shutdown()
    deadline = time.time() + 10
    while time.time() < deadline and any(w.proc.is_alive()
                                         for w in workers):
        time.sleep(0.05)
    assert all(not w.proc.is_alive() for w in workers), pids
    # idempotent
    mgr.shutdown()


def test_partial_factories_are_spawn_picklable():
    """The documented pattern for process hosts: module-level functions
    (optionally via functools.partial) ship; closures do not."""
    import pickle
    fac = functools.partial(FnPellet, _double)
    rebuilt = pickle.loads(pickle.dumps(fac, protocol=5))
    assert rebuilt().compute(21) == 42
    with pytest.raises(Exception):
        pickle.dumps(lambda: FnPellet(_double), protocol=5)
