"""Adaptive micro-batched data path: semantics preserved, accounting exact.

Covers the engine's batched dispatch (`Channel.pop_up_to`/`put_many`,
the 'batch' work kind, amortized routing), the `compute_batch` pellet
contract (including `FnPellet(vectorized=True)`), the `.batch(...)`
Session API knob, and the guarantees the tentpole must not bend: per-channel
FIFO, per-key routing determinism, landmark ordering, exact FlakeStats, and
B adapting back to 1 when queues drain.
"""
import threading

import pytest

from conftest import wait_until
from repro.api import Flow
from repro.api.errors import CompositionError
from repro.core import (Coordinator, Drop, FloeGraph, FnMapper, FnPellet,
                        FnReducer, Message, PushPellet, add_mapreduce,
                        stable_hash)
from repro.core.engine import Channel
from repro.core.message import landmark


def _is_special(m):
    return not m.is_data()


# -- Channel batch primitives --------------------------------------------------

def test_pop_up_to_respects_limit_and_order():
    ch = Channel()
    for i in range(10):
        ch.put(Message(payload=i))
    got = ch.pop_up_to(4)
    assert [m.payload for m in got] == [0, 1, 2, 3]
    assert [m.payload for m in ch.pop_up_to()] == [4, 5, 6, 7, 8, 9]
    assert ch.pop_up_to() == []


def test_pop_up_to_never_spans_a_boundary():
    ch = Channel()
    ch.put(Message(payload="d1"))
    ch.put(Message(payload="d2"))
    ch.put(landmark("L"))
    ch.put(Message(payload="d3"))
    batch = ch.pop_up_to(10, stop=_is_special)
    assert [m.payload for m in batch] == ["d1", "d2"]
    # a boundary message at the head pops ALONE
    batch = ch.pop_up_to(10, stop=_is_special)
    assert len(batch) == 1 and batch[0].landmark
    assert [m.payload for m in ch.pop_up_to(10, stop=_is_special)] == ["d3"]


def test_unpop_restores_head():
    ch = Channel()
    ch.put(Message(payload=1))
    ch.put(Message(payload=2))
    m = ch.try_pop()
    ch.unpop(m)
    assert [x.payload for x in ch.pop_up_to()] == [1, 2]


def test_put_many_preserves_capacity_and_order():
    ch = Channel(capacity=5)
    ch.put_many([Message(payload=i) for i in range(5)])
    done = threading.Event()

    def overflow():
        ch.put_many([Message(payload=i) for i in range(5, 8)], timeout=10)
        done.set()

    t = threading.Thread(target=overflow, daemon=True)
    t.start()
    assert not done.wait(0.05)          # blocked: channel full (backpressure)
    assert len(ch.pop_up_to(3)) == 3    # make room
    assert done.wait(5)
    t.join()
    assert [m.payload for m in ch.pop_up_to()] == [3, 4, 5, 6, 7]


def test_put_many_timeout_reports_partial_admission():
    ch = Channel(capacity=3)
    with pytest.raises(TimeoutError) as exc:
        ch.put_many([Message(payload=i) for i in range(5)], timeout=0.05)
    assert exc.value.appended == 3   # callers roll back the remainder
    assert [m.payload for m in ch.pop_up_to()] == [0, 1, 2]


def test_put_many_notifies_consumer_per_chunk():
    ch = Channel(capacity=2)
    wakes = []
    ch._on_put = lambda: wakes.append(len(ch))
    consumed = []

    def consume():
        while len(consumed) < 6:
            got = ch.pop_up_to()
            consumed.extend(got)
            if not got:
                threading.Event().wait(0.002)

    t = threading.Thread(target=consume, daemon=True)
    t.start()
    ch.put_many([Message(payload=i) for i in range(6)], timeout=10)
    t.join(timeout=10)
    assert [m.payload for m in consumed] == list(range(6))
    assert len(wakes) >= 2   # chunked admission notified along the way


# -- FIFO + determinism under batching ----------------------------------------

def test_batched_dispatch_preserves_fifo_per_channel():
    n = 400
    g = FloeGraph("fifo")
    g.add("p", lambda: FnPellet(lambda x: x * 2, sequential=True))
    coord = Coordinator(g).start()
    try:
        coord.flakes["p"].pause()
        for i in range(n):
            coord.inject("p", i)
        coord.flakes["p"].resume()
        assert coord.run_until_quiescent(timeout=60)
        out = [m.payload for m in coord.drain_outputs() if m.is_data()]
        assert out == [i * 2 for i in range(n)]   # exact order, no loss
        assert coord.flakes["p"].stats.max_batch > 1   # really batched
    finally:
        coord.stop()


def test_batched_hash_routing_is_per_key_deterministic():
    n, n_sinks = 300, 4
    g = FloeGraph("hash")
    g.add("m", lambda: FnMapper(lambda x: [(x % 8, x)]))
    for i in range(n_sinks):
        g.add(f"s{i}", lambda i=i: FnPellet(lambda x, i=i: (i, x),
                                            sequential=True))
        g.connect("m", f"s{i}", split="hash")
    coord = Coordinator(g).start()
    try:
        coord.flakes["m"].pause()
        for i in range(n):
            coord.inject("m", i)
        coord.flakes["m"].resume()
        assert coord.run_until_quiescent(timeout=60)
        out = [m.payload for m in coord.drain_outputs() if m.is_data()]
        assert len(out) == n
        for sink_idx, value in out:
            # batched split evaluation must place each key exactly where
            # the per-message HashSplit would
            assert sink_idx == stable_hash(value % 8) % n_sinks
    finally:
        coord.stop()


def test_landmark_never_overtakes_data_across_batches():
    n = 250
    g = FloeGraph("lm")
    g.add("p", lambda: FnPellet(lambda x: x, sequential=True))
    coord = Coordinator(g).start()
    try:
        coord.flakes["p"].pause()
        for i in range(n):
            coord.inject("p", i)
        coord.inject_landmark("p", tag="flush")
        for i in range(n, 2 * n):
            coord.inject("p", i)
        coord.flakes["p"].resume()
        assert coord.run_until_quiescent(timeout=60)
        out = coord.drain_outputs()
        kinds = [("lm" if m.landmark else m.payload) for m in out]
        assert kinds == list(range(n)) + ["lm"] + list(range(n, 2 * n))
    finally:
        coord.stop()


def test_batched_shuffle_reduce_counts_are_exact():
    """Flood a 2x4 MapReduce; every (key, count) must be exact despite
    batched mappers, amortized hash routing, and fan-in landmark alignment."""
    n = 640   # divisible by 16 so every key's exact count is n // 16
    g = FloeGraph("wc")
    g.add("src", lambda: FnPellet(lambda x: x, sequential=True))
    add_mapreduce(g, prefix="b",
                  mapper_factory=lambda: FnMapper(lambda x: [(x % 16, 1)]),
                  reducer_factory=lambda: FnReducer(lambda: 0,
                                                    lambda a, v: a + v),
                  n_mappers=2, n_reducers=4, source="src")
    coord = Coordinator(g).start()
    try:
        for i in range(n):
            coord.inject("src", i)
        coord.inject_landmark("src")
        assert coord.run_until_quiescent(timeout=60)
        counts = dict(m.payload for m in coord.drain_outputs()
                      if m.is_data())
        assert sum(counts.values()) == n
        assert counts == {k: n // 16 for k in range(16)}
    finally:
        coord.stop()


# -- accounting ----------------------------------------------------------------

def test_flakestats_exact_under_batched_accounting():
    n = 500
    g = FloeGraph("stats")
    g.add("p", lambda: FnPellet(
        lambda x: Drop if x % 2 else x, sequential=True))
    coord = Coordinator(g).start()
    try:
        coord.flakes["p"].pause()
        for i in range(n):
            coord.inject("p", i)
        coord.flakes["p"].resume()
        assert coord.run_until_quiescent(timeout=60)
        st = coord.flakes["p"].stats
        assert st.arrived == n
        assert st.processed == n
        assert st.emitted == n // 2
        assert st.selectivity == pytest.approx(0.5)
        assert st.max_batch > 1
    finally:
        coord.stop()


def test_adaptive_batch_shrinks_back_to_one():
    g = FloeGraph("adapt")
    g.add("p", lambda: FnPellet(lambda x: x, sequential=True))
    coord = Coordinator(g).start()
    try:
        flake = coord.flakes["p"]
        flake.pause()
        for i in range(300):
            coord.inject("p", i)
        flake.resume()
        assert coord.run_until_quiescent(timeout=60)
        assert flake.stats.max_batch > 1          # grew under backlog
        for i in range(5):                         # trickle: B must be 1
            coord.inject("p", i)
            assert coord.run_until_quiescent(timeout=60)
            assert flake.stats.last_batch == 1
    finally:
        coord.stop()


def test_compute_batch_length_mismatch_recovers_per_message():
    """A batch-level bug (broken override) is surfaced as an engine error
    but the data is recovered through per-message compute — no loss."""
    class Bad(PushPellet):
        sequential = True

        def compute(self, payload):
            return payload

        def compute_batch(self, payloads):
            return payloads[:-1]   # one result short

    g = FloeGraph("bad")
    g.add("p", Bad)
    coord = Coordinator(g).start()
    try:
        coord.flakes["p"].pause()
        for i in range(10):
            coord.inject("p", i)
        coord.flakes["p"].resume()
        assert coord.run_until_quiescent(timeout=60)
        assert coord.errors and isinstance(coord.errors[0][1], ValueError)
        st = coord.flakes["p"].stats
        assert st.arrived == st.processed == 10   # credits never leak
        out = [m.payload for m in coord.drain_outputs() if m.is_data()]
        assert out == list(range(10))             # recovered, in order
    finally:
        coord.stop()


def test_raising_message_does_not_drop_its_batchmates():
    """Error semantics stay exactly per-message under batching: only the
    raising message is dropped (and recorded), the rest of its micro-batch
    is still delivered, and every message's side effects run EXACTLY once
    (no re-execution of batchmates on failure)."""
    calls = []

    def fragile(x):
        calls.append(x)
        if x == 13:
            raise RuntimeError("boom")
        return x

    g = FloeGraph("frag")
    g.add("p", lambda: FnPellet(fragile, sequential=True))
    coord = Coordinator(g).start()
    try:
        coord.flakes["p"].pause()
        for i in range(40):
            coord.inject("p", i)
        coord.flakes["p"].resume()
        assert coord.run_until_quiescent(timeout=60)
        assert coord.flakes["p"].stats.max_batch > 1   # really batched
        out = sorted(m.payload for m in coord.drain_outputs() if m.is_data())
        assert out == [i for i in range(40) if i != 13]
        errs = [e for _, e in coord.errors]
        assert len(errs) == 1 and isinstance(errs[0], RuntimeError)
        assert sorted(calls) == list(range(40))        # exactly once each
        st = coord.flakes["p"].stats
        assert st.arrived == st.processed == 40
        assert st.emitted == 39
    finally:
        coord.stop()


def test_failing_vectorized_batch_recovers_per_message():
    """A raising vectorized override is recovered by re-running the batch
    per message: only the bad message is dropped and recorded."""
    def vec(xs):
        if any(x == 7 for x in xs) and len(xs) > 1:
            raise RuntimeError("vectorized boom")
        return [x * 10 if x != 7 else (_ for _ in ()).throw(
            RuntimeError("boom")) for x in xs]

    g = FloeGraph("vfrag")
    g.add("p", lambda: FnPellet(vec, vectorized=True, sequential=True))
    coord = Coordinator(g).start()
    try:
        coord.flakes["p"].pause()
        for i in range(20):
            coord.inject("p", i)
        coord.flakes["p"].resume()
        assert coord.run_until_quiescent(timeout=60)
        out = sorted(m.payload for m in coord.drain_outputs() if m.is_data())
        assert out == [i * 10 for i in range(20) if i != 7]
        assert any(isinstance(e, RuntimeError) for _, e in coord.errors)
    finally:
        coord.stop()


def test_custom_split_policy_honored_under_batching():
    """Split policies are a public extension point; a custom choose() must
    see every message whether B is 1 or 100 — even with a single target."""
    from repro.core import Split
    from repro.core.patterns import SPLITS

    class EvenOnly(Split):
        def choose(self, msg, n_edges, queue_depths):
            return [0] if msg.payload % 2 == 0 else []

    SPLITS["even_only"] = EvenOnly
    try:
        g = FloeGraph("csp")
        g.add("src", lambda: FnPellet(lambda x: x, sequential=True))
        g.add("dst", lambda: FnPellet(lambda x: x, sequential=True))
        g.connect("src", "dst", split="even_only")
        coord = Coordinator(g).start()
        try:
            coord.flakes["src"].pause()
            for i in range(100):
                coord.inject("src", i)
            coord.flakes["src"].resume()
            assert coord.run_until_quiescent(timeout=60)
            assert coord.flakes["src"].stats.max_batch > 1  # really batched
            out = sorted(m.payload for m in coord.drain_outputs()
                         if m.is_data())
            assert out == [i for i in range(100) if i % 2 == 0]
        finally:
            coord.stop()
    finally:
        SPLITS.pop("even_only", None)


def test_routing_failure_releases_inflight_credits():
    """A split policy that raises mid-routing must not wedge quiescence:
    the consumed credits are released and the error is recorded."""
    from repro.core import Split
    from repro.core.patterns import SPLITS

    class Exploding(Split):
        def choose(self, msg, n_edges, queue_depths):
            raise RuntimeError("router down")

    SPLITS["exploding"] = Exploding
    try:
        g = FloeGraph("rf")
        g.add("src", lambda: FnPellet(lambda x: x, sequential=True))
        g.add("dst", lambda: FnPellet(lambda x: x))
        g.connect("src", "dst", split="exploding")
        coord = Coordinator(g).start()
        try:
            coord.flakes["src"].pause()
            for i in range(20):
                coord.inject("src", i)
            coord.flakes["src"].resume()
            # quiescence must still be reachable despite every route failing
            assert coord.run_until_quiescent(timeout=30)
            assert any(isinstance(e, RuntimeError) for _, e in coord.errors)
        finally:
            coord.stop()
    finally:
        SPLITS.pop("exploding", None)


# -- vectorized pellets --------------------------------------------------------

def test_vectorized_fnpellet_runs_once_per_batch():
    n = 200
    calls = []

    def batched_double(xs):
        calls.append(len(xs))
        return [x * 2 for x in xs]

    g = FloeGraph("vec")
    g.add("p", lambda: FnPellet(batched_double, vectorized=True,
                                sequential=True))
    coord = Coordinator(g).start()
    try:
        coord.flakes["p"].pause()
        for i in range(n):
            coord.inject("p", i)
        coord.flakes["p"].resume()
        assert coord.run_until_quiescent(timeout=60)
        out = [m.payload for m in coord.drain_outputs() if m.is_data()]
        assert out == [i * 2 for i in range(n)]
        assert sum(calls) == n
        assert len(calls) < n          # genuinely amortized
        assert max(calls) > 1
    finally:
        coord.stop()


def test_vectorized_single_message_semantics():
    p = FnPellet(lambda xs: [x + 1 for x in xs], vectorized=True)
    assert p.compute(41) == 42
    assert p.compute_batch([1, 2, 3]) == [2, 3, 4]


# -- Session API knob ----------------------------------------------------------

def test_flow_batch_annotation_compiles_onto_flake():
    flow = Flow("b")
    stage = flow.pellet("p", lambda: FnPellet(lambda x: x))
    stage.batch(32, max_wait_ms=5.0)
    with flow.session() as s:
        flake = s.coordinator.flakes["p"]
        assert flake.batch_max == 32
        assert flake.batch_wait == pytest.approx(0.005)
        s.set_batch("p", max_size=1)          # runtime disable
        assert flake.batch_max == 1
        s.inject("p", 7)
        assert s.results() == [7]


def test_flow_batch_annotation_validates_eagerly():
    flow = Flow("bad")
    stage = flow.pellet("p", lambda: FnPellet(lambda x: x))
    with pytest.raises(CompositionError, match="max_size"):
        stage.batch(0)
    with pytest.raises(CompositionError, match="max_wait_ms"):
        stage.batch(8, max_wait_ms=-1)


def test_batch_rejected_for_non_push_stages():
    """The knob is a no-op for pull/window/tuple pellets, so accepting it
    would silently do nothing — eager validation rejects it instead."""
    from repro.core import FnReducer, WindowPellet

    class Win(WindowPellet):
        window = 4

        def compute(self, payloads):
            return sum(payloads)

    flow = Flow("nonpush")
    red = flow.pellet("red", lambda: FnReducer(lambda: 0, lambda a, v: a + v))
    win = flow.pellet("win", Win)
    for stage in (red, win):
        with pytest.raises(CompositionError, match="push pellets only"):
            stage.batch(32)
    with flow.session() as s:
        from repro.api.errors import SessionStateError
        with pytest.raises(SessionStateError, match="push pellets only"):
            s.set_batch("red", max_size=32)


def test_set_batch_validates_at_runtime():
    from repro.api.errors import SessionStateError
    flow = Flow("rt")
    flow.pellet("p", lambda: FnPellet(lambda x: x))
    with flow.session() as s:
        with pytest.raises(SessionStateError, match="max_size"):
            s.set_batch("p", max_size=0)
        with pytest.raises(SessionStateError, match="max_wait_ms"):
            s.set_batch("p", max_size=8, max_wait_ms=-5)


@pytest.mark.parametrize("sequential", [True, False])
def test_batch_wait_coalesces_a_partial_batch(sequential):
    """The linger must engage for pooled (non-sequential) stages too —
    that is the README's recommended vectorized configuration."""
    flow = Flow("wait")
    flow.pellet("p", lambda: FnPellet(lambda x: x, sequential=sequential)) \
        .batch(64, max_wait_ms=25.0)
    with flow.session() as s:
        flake = s.coordinator.flakes["p"]
        flake.pause()
        for i in range(10):
            s.inject("p", i)
        flake.resume()
        assert sorted(s.results()) == list(range(10))
        # all 10 queued messages (< max_size) coalesced into ONE dispatch
        # after the bounded linger
        assert flake.stats.batches == 1
        assert flake.stats.last_batch == 10


def test_batch_wait_does_not_delay_landmarks():
    """Specials can never be part of a batch, so a lingering stage must
    dispatch them immediately instead of burning the full wait."""
    import time as _time
    flow = Flow("lmwait")
    flow.pellet("p", lambda: FnPellet(lambda x: x, sequential=True)) \
        .batch(256, max_wait_ms=10_000.0)   # pathological 10s linger
    with flow.session() as s:
        t0 = _time.time()
        s.inject_landmark("p", tag="flush")
        out = s.drain(timeout=5)
        assert _time.time() - t0 < 5
        assert any(m.landmark for m in out)


def test_set_batch_clears_pending_linger():
    flow = Flow("clear")
    flow.pellet("p", lambda: FnPellet(lambda x: x, sequential=True)) \
        .batch(64, max_wait_ms=5_000.0)
    with flow.session() as s:
        flake = s.coordinator.flakes["p"]
        s.inject("p", 1)          # starts a 5s linger (1 < 64)
        assert wait_until(lambda: flake._batch_deadline is not None)
        s.set_batch("p", max_size=64, max_wait_ms=0.0)
        # the dropped linger must not strand the queued message
        assert s.results(timeout=5) == [1]
        assert flake._batch_deadline is None


def test_batched_sink_collection_preserves_cross_port_emit_order():
    """Sink-collected emissions from different out-ports share one output
    list; batching must not regroup them by port."""
    class TwoPort(PushPellet):
        sequential = True
        out_ports = ("a", "b")

        def compute(self, x):
            return {"a": ("a", x), "b": ("b", x)}

    g = FloeGraph("ports")
    g.add("p", TwoPort)
    coord = Coordinator(g).start()
    try:
        coord.flakes["p"].pause()
        for i in range(60):
            coord.inject("p", i)
        coord.flakes["p"].resume()
        assert coord.run_until_quiescent(timeout=60)
        assert coord.flakes["p"].stats.max_batch > 1   # really batched
        out = [m.payload for m in coord.drain_outputs() if m.is_data()]
        expected = []
        for i in range(60):
            expected += [("a", i), ("b", i)]   # interleaved emit order
        assert out == expected
    finally:
        coord.stop()


def test_batched_routing_preserves_cross_port_order_to_shared_destination():
    """Two out-ports wired to the SAME downstream flake: the downstream
    channel must observe the exact emit interleaving, not port bursts."""
    class TwoPort(PushPellet):
        sequential = True
        out_ports = ("a", "b")

        def compute(self, x):
            return {"a": ("a", x), "b": ("b", x)}

    g = FloeGraph("xport")
    g.add("p", TwoPort)
    g.add("q", lambda: FnPellet(lambda x: x, sequential=True))
    g.connect("p", "q", src_port="a")
    g.connect("p", "q", src_port="b")
    coord = Coordinator(g).start()
    try:
        coord.flakes["p"].pause()
        for i in range(50):
            coord.inject("p", i)
        coord.flakes["p"].resume()
        assert coord.run_until_quiescent(timeout=60)
        assert coord.flakes["p"].stats.max_batch > 1   # really batched
        out = [m.payload for m in coord.drain_outputs() if m.is_data()]
        expected = []
        for i in range(50):
            expected += [("a", i), ("b", i)]
        assert out == expected
    finally:
        coord.stop()


# -- speculative execution keeps its per-message path --------------------------

def test_speculation_forces_per_message_dispatch():
    g = FloeGraph("spec")
    g.add("p", lambda: FnPellet(lambda x: x))
    coord = Coordinator(g, speculative_timeout=5.0).start()
    try:
        flake = coord.flakes["p"]
        assert flake._batch_limit() == 1
        flake.pause()
        for i in range(50):
            coord.inject("p", i)
        flake.resume()
        assert coord.run_until_quiescent(timeout=60)
        assert flake.stats.max_batch == 1
        out = sorted(m.payload for m in coord.drain_outputs() if m.is_data())
        assert out == list(range(50))
    finally:
        coord.stop()


def test_speculative_backup_does_not_leak_semaphore_slots():
    """Backup tasks bypass the instance pool; they must not release slots
    they never acquired (the admission cap would loosen by one per backup)."""
    import time as _time

    def slow_once(x):
        if x == 0:
            _time.sleep(0.2)
        return x

    g = FloeGraph("slots")
    g.add("p", lambda: FnPellet(slow_once), cores=2)
    coord = Coordinator(g, speculative_timeout=0.05).start()
    try:
        for i in range(5):
            coord.inject("p", i)
        assert coord.run_until_quiescent(timeout=60)
        assert wait_until(
            lambda: coord.flakes["p"]._sem._in_use == 0, timeout=10)
        assert coord.flakes["p"]._sem._in_use == 0   # never negative
    finally:
        coord.stop()


# -- message seq block allocation ---------------------------------------------

def test_seq_ids_unique_across_threads():
    seqs, lock = [], threading.Lock()

    def mint(k=800):
        local = [Message(payload=None).seq for _ in range(k)]
        with lock:
            seqs.extend(local)

    threads = [threading.Thread(target=mint) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(set(seqs)) == len(seqs) == 8 * 800


def test_seq_monotonic_per_thread():
    a = Message(payload=1).seq
    b = Message(payload=2).seq
    assert b > a
