"""Serving engine: continuous batching, ragged decode, live model update."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import Model
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def small():
    cfg = registry.get("qwen3-1.7b").scaled_down()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_ragged_decode_matches_per_sequence_forward(small):
    """Per-slot lengths: decoding rows parked at different positions gives
    the same logits as each row decoded alone (continuous batching
    correctness)."""
    cfg, model, params = small
    S1, S2, cap = 6, 10, 16
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, S1), 0,
                            cfg.vocab_size)
    t2 = jax.random.randint(jax.random.PRNGKey(2), (1, S2), 0,
                            cfg.vocab_size)
    # individual decode
    outs = []
    for t in (t1, t2):
        _, c = model.prefill(params, {"tokens": t[:, :-1]}, max_len=cap)
        lg, _ = model.decode(params, c, t[:, -1:])
        outs.append(np.asarray(lg[0, 0], np.float32))
    # batched ragged decode: build a batch-2 cache with different lengths
    _, c1 = model.prefill(params, {"tokens": t1[:, :-1]}, max_len=cap)
    _, c2 = model.prefill(params, {"tokens": t2[:, :-1]}, max_len=cap)

    def merge(a, b):
        if a.ndim >= 1 and a.shape != b.shape:  # can't happen: same max_len
            raise AssertionError
        # find batch axis: where both have size 1 and dim matches layout
        return a  # placeholder

    # assemble batched cache through the engine's splice helper
    from repro.serving.engine import _splice_batched
    from repro.models.common import shapes_tree
    layout = model.cache_layout(2, cap)
    batched = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                           shapes_tree(layout))
    batched = jax.tree.map(
        lambda full, one: _splice_batched(full, one, 0, 2), batched, c1)
    batched = jax.tree.map(
        lambda full, one: _splice_batched(full, one, 1, 2), batched, c2)
    toks = jnp.concatenate([t1[:, -1:], t2[:, -1:]], axis=0)
    lg, newc = model.decode(params, batched, toks)
    got = np.asarray(lg[:, 0], np.float32)
    for i in range(2):
        rel = (np.max(np.abs(got[i] - outs[i]))
               / (np.max(np.abs(outs[i])) + 1e-9))
        assert rel < 0.03, f"row {i}: rel={rel:.4f}"
    assert list(np.asarray(newc["len"])) == [S1, S2]


def test_engine_serves_batched_requests(small):
    cfg, model, params = small
    eng = ServingEngine(cfg, params, n_slots=2, max_len=32)
    rids = [eng.submit(np.arange(4) + i, max_new_tokens=5) for i in range(5)]
    eng.run(until_idle=True, max_steps=200)
    assert len(eng.responses) == 5
    got = {r.rid for r in eng.responses}
    assert got == set(rids)
    for r in eng.responses:
        assert len(r.tokens) == 5
        assert all(0 <= t < cfg.vocab_size for t in r.tokens)


def test_engine_deterministic_across_batching(small):
    """The same prompt yields the same tokens whether served alone or
    alongside other requests (slot isolation)."""
    cfg, model, params = small
    prompt = np.arange(6)
    eng1 = ServingEngine(cfg, params, n_slots=1, max_len=32)
    eng1.submit(prompt, max_new_tokens=4)
    eng1.run()
    alone = next(r.tokens for r in eng1.responses)
    eng2 = ServingEngine(cfg, params, n_slots=3, max_len=32)
    eng2.submit(np.arange(8) * 3 % cfg.vocab_size, max_new_tokens=6)
    rid = eng2.submit(prompt, max_new_tokens=4)
    eng2.submit(np.arange(5) * 7 % cfg.vocab_size, max_new_tokens=3)
    eng2.run()
    together = next(r.tokens for r in eng2.responses if r.rid == rid)
    assert together == alone


def test_live_model_update_sync(small):
    """§II.B dynamic task update in serving: weights swap mid-stream without
    dropping requests; responses carry the model version (update landmark)."""
    cfg, model, params = small
    params2 = model.init(jax.random.PRNGKey(42))
    eng = ServingEngine(cfg, params, n_slots=2, max_len=32)
    eng.submit(np.arange(4), max_new_tokens=3)
    eng.run()                                   # v0 serves request 0
    eng.submit(np.arange(4), max_new_tokens=3)
    eng.step()                                  # request 1 in flight on v0
    v = eng.update_params(params2, mode="sync")  # swap mid-request
    assert v == 1
    eng.run()
    eng.submit(np.arange(4), max_new_tokens=3)   # request 2 fully on v1
    eng.run()
    by_rid = {r.rid: r for r in eng.responses}
    assert by_rid[0].model_version == 0
    assert by_rid[1].model_version == 1          # landmark: swapped mid-run
    assert by_rid[2].model_version == 1
    assert len(by_rid) == 3
    # v0 and v1 produce different generations for the same prompt
    assert by_rid[0].tokens != by_rid[2].tokens


def test_live_model_update_async_zero_downtime(small):
    cfg, model, params = small
    params2 = model.init(jax.random.PRNGKey(7))
    eng = ServingEngine(cfg, params, n_slots=1, max_len=32)
    eng.submit(np.arange(4), max_new_tokens=4)
    eng.step()
    eng.update_params(params2, mode="async")    # in-flight keeps version 0
    eng.run()
    assert eng.responses[0].model_version == 0  # old logic ran to completion
    eng.submit(np.arange(4), max_new_tokens=4)
    eng.run()
    assert eng.responses[1].model_version == 1
