"""Per-architecture smoke tests (reduced configs, CPU).

Each assigned architecture instantiates a topology-preserving reduced config
and runs one forward + one train step, asserting output shapes and no NaNs;
plus a prefill→decode consistency check against the full forward (exact for
deterministic families; loose for MoE where capacity dropping depends on
batch composition).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL, registry
from repro.configs.shapes import ALL_SHAPES, shape_applicable
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.optim import init_state

ARCHS = [c.name for c in ALL]


def make_batch(cfg, B, S, key=0, train=True):
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if train:
        batch["labels"] = jax.random.randint(
            jax.random.PRNGKey(key + 1), (B, S), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["images"] = jax.random.normal(
            jax.random.PRNGKey(key + 2), (B, cfg.n_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(key + 2), (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = registry.get(arch).scaled_down()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    logits, cache, aux = m.forward(params, make_batch(cfg, B, S, train=False))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = registry.get(arch).scaled_down()
    step, model = make_train_step(cfg)
    state = init_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(cfg, 4, 16)
    state, metrics = jax.jit(step)(state, batch)
    assert int(state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    l0 = jax.tree.leaves(state.params)[0]
    assert np.all(np.isfinite(np.asarray(l0, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    cfg = registry.get(arch).scaled_down()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = make_batch(cfg, B, S, train=False)
    logits, _, _ = m.forward(params, batch)
    bp = dict(batch)
    bp["tokens"] = batch["tokens"][:, :-1]
    _, cache = m.prefill(params, bp, max_len=S + 4)
    lg, cache = m.decode(params, cache, batch["tokens"][:, -1:])
    ref = np.asarray(logits[:, -1, :], np.float32)
    got = np.asarray(lg[:, 0, :], np.float32)
    rel = np.max(np.abs(ref - got)) / (np.max(np.abs(ref)) + 1e-9)
    # MoE: token dropping depends on batch composition (capacity is per
    # forward call), so prefill(S-1) and forward(S) legitimately route a few
    # tokens differently — only a loose bound is meaningful there
    tol = 0.25 if cfg.moe is not None else 0.02
    assert rel < tol, f"{arch}: decode/forward mismatch rel={rel:.4f}"
    assert np.all(np.asarray(cache["len"]) == S)  # per-sequence lengths


@pytest.mark.parametrize("arch", ARCHS)
def test_gradient_accumulation_matches_single_batch(arch):
    """accum_steps microbatching must match the full-batch gradient step."""
    cfg = registry.get(arch).scaled_down()
    cfg1 = dataclasses.replace(cfg, accum_steps=1)
    cfg2 = dataclasses.replace(cfg, accum_steps=2)
    step1, m1 = make_train_step(cfg1)
    step2, m2 = make_train_step(cfg2)
    params = m1.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 8)
    s1, met1 = jax.jit(step1)(init_state(params), batch)
    s2, met2 = jax.jit(step2)(init_state(params), batch)
    # MoE capacity depends on per-call token count -> exact match only for
    # non-MoE families; MoE checked loosely
    l1 = np.asarray(jax.tree.leaves(s1.master)[0], np.float32)
    l2 = np.asarray(jax.tree.leaves(s2.master)[0], np.float32)
    tol = 5e-2 if cfg.moe is not None else 5e-3
    assert np.max(np.abs(l1 - l2)) < tol


def test_scan_vs_unrolled_layers_agree():
    """scan_layers=False (roofline unrolled mode) is numerically identical."""
    cfg = registry.get("qwen3-1.7b").scaled_down()
    m_scan = Model(cfg)
    m_loop = Model(dataclasses.replace(cfg, scan_layers=False))
    params = m_scan.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 8, train=False)
    a, _, _ = m_scan.forward(params, batch)
    b, _, _ = m_loop.forward(params, batch)
    # bf16: scan vs unrolled fuse/reassociate differently -> one-ulp noise
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=8e-3)


def test_param_count_estimates_match_actual():
    """Analytic param counts (used for MODEL_FLOPS) track actual trees."""
    for arch in ARCHS:
        cfg = registry.get(arch)
        m = Model(cfg)
        shapes = jax.tree.leaves(m.param_shapes())
        actual = sum(int(np.prod(s.shape)) for s in shapes)
        est = cfg.param_count_estimate()
        assert abs(actual - est) / actual < 0.06, \
            f"{arch}: actual={actual:.3e} est={est:.3e}"


def test_full_param_counts_sane():
    """Full (unreduced) configs land near their nameplate sizes."""
    expect = {
        "smollm-360m": (0.3e9, 0.45e9),
        "qwen3-1.7b": (1.4e9, 2.1e9),
        "h2o-danube-3-4b": (3.0e9, 4.5e9),
        "qwen3-14b": (13e9, 16e9),
        "llama-3.2-vision-90b": (80e9, 95e9),
        "falcon-mamba-7b": (6.5e9, 8e9),
        "zamba2-2.7b": (2.2e9, 3.2e9),
        "dbrx-132b": (125e9, 140e9),
        "moonshot-v1-16b-a3b": (24e9, 30e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        m = Model(registry.get(arch))
        actual = sum(int(np.prod(s.shape))
                     for s in jax.tree.leaves(m.param_shapes()))
        assert lo <= actual <= hi, f"{arch}: {actual:.3e} not in [{lo:.0e},{hi:.0e}]"


def test_cell_applicability_table():
    """40 assigned cells: long_500k runs only for SSM/hybrid families."""
    run, skipped = 0, []
    for cfg in ALL:
        for sh in ALL_SHAPES:
            ok, why = shape_applicable(cfg, sh)
            if ok:
                run += 1
            else:
                skipped.append((cfg.name, sh.name))
    assert run + len(skipped) == 40
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == {
        "smollm-360m", "qwen3-1.7b", "h2o-danube-3-4b", "qwen3-14b",
        "llama-3.2-vision-90b", "dbrx-132b", "moonshot-v1-16b-a3b",
        "whisper-large-v3"}
