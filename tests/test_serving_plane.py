"""Serving plane: multi-column carriers, exactly-once sinks, LM dataflow.

Covers PR 8's tentpole and satellites:

* multi-column ``ArrayBatch`` (dict-of-arrays) semantics
* ``__floe_state__`` carry-over across in-place task updates
* ``Flow.sink(..., exactly_once=True)`` dedup end-to-end
* the serving dataflow itself — continuous-batching census, kernel-vs-ref
  numerics *through the dataflow*, checkpoint→kill→restore of in-flight
  generations, and zero-loss live weight hot-swap with version tags.
"""
import pickle
import time

import numpy as np
import pytest
from conftest import wait_until

from repro import Flow, FnPellet, PushPellet, Session
from repro.core.arraybatch import ArrayBatch
from repro.serving import (LMSpec, Scheduler, build_serving_flow,
                           make_request, swapped_flow)

#: one tiny geometry shared by every dataflow test — jit caches per
#: (spec, shapes), so reuse keeps the suite to a handful of compiles
SPEC = LMSpec(vocab=16, n_heads=2, n_kv_heads=1, head_dim=4, n_layers=1,
              max_len=16)


def _responses(results):
    return sorted((r for r in results if isinstance(r, dict) and "rid" in r),
                  key=lambda r: r["rid"])


# ---------------------------------------------------------------------------
# satellite: multi-column ArrayBatch
# ---------------------------------------------------------------------------

class TestMultiColumnArrayBatch:
    def test_stack_dict_payloads_columnwise(self):
        rows = [{"tok": np.int32(i), "slot": np.int32(9 - i),
                 "vec": np.full(3, float(i))} for i in range(4)]
        ab = ArrayBatch.try_stack(rows)
        assert ab is not None and len(ab) == 4
        assert set(ab.columns) == {"tok", "slot", "vec"}
        assert ab.columns["vec"].shape == (4, 3)
        np.testing.assert_array_equal(ab.columns["tok"], [0, 1, 2, 3])

    def test_row_access_and_messages(self):
        ab = ArrayBatch({"a": np.arange(3), "b": np.arange(3) * 10.0},
                        seqs=[7, 8, 9])
        row = ab._row(1)
        assert row == {"a": 1, "b": 10.0}
        msgs = ab.to_messages()
        assert [m.payload["b"] for m in msgs] == [0.0, 10.0, 20.0]
        assert msgs[2].meta["parent_seq"] == 9

    def test_take_slices_every_column(self):
        ab = ArrayBatch({"x": np.arange(5), "y": np.arange(5) * 2},
                        keys=list("abcde"))
        sub = ab.take([4, 0])
        assert len(sub) == 2
        np.testing.assert_array_equal(sub.columns["y"], [8, 0])
        assert sub.keys == ["e", "a"]

    def test_ragged_or_heterogeneous_dicts_decline(self):
        # different key sets -> decline
        assert ArrayBatch.try_stack([{"a": 1}, {"b": 2}]) is None
        # ragged column shapes -> decline
        assert ArrayBatch.try_stack(
            [{"a": np.zeros(2)}, {"a": np.zeros(3)}]) is None
        # object column -> decline
        assert ArrayBatch.try_stack([{"a": object()}, {"a": object()}]) is None

    def test_constructor_rejects_ragged_columns(self):
        with pytest.raises(ValueError):
            ArrayBatch({"a": np.zeros(2), "b": np.zeros(3)})
        with pytest.raises(ValueError):
            ArrayBatch({})

    def test_pickle_roundtrip_materializes_host(self):
        ab = ArrayBatch({"a": np.arange(4), "b": np.ones((4, 2))})
        ab2 = pickle.loads(pickle.dumps(ab))
        assert len(ab2) == 4
        np.testing.assert_array_equal(ab2.columns["a"], np.arange(4))

    def test_single_array_unchanged(self):
        ab = ArrayBatch.try_stack([np.ones(2), np.ones(2)])
        assert ab.columns is None and ab.array.shape == (2, 2)
        assert ab._row(0).shape == (2,)


# ---------------------------------------------------------------------------
# satellite groundwork: __floe_state__ survives an in-place task update
# ---------------------------------------------------------------------------

class _Accum(PushPellet):
    sequential = True
    __floe_state__ = ("total",)

    def __init__(self, gain):
        self.gain = gain
        self.total = 0

    def compute(self, payload):
        self.total += payload
        return self.total * self.gain


class TestSwapCarriesInstanceState:
    def test_swap_pellet_carries_floe_state(self):
        flow = Flow("carry")
        acc = flow.pellet("acc", lambda: _Accum(1))
        with flow.session() as s:
            s.inject(acc, 5)
            assert s.results(timeout=10) == [5]
            s.update(acc, lambda: _Accum(10))
            s.inject(acc, 1)
            # total=5 carried across the swap: (5+1)*10, not 1*10
            assert s.results(timeout=10) == [60]


# ---------------------------------------------------------------------------
# satellite: exactly-once sink
# ---------------------------------------------------------------------------

class TestExactlyOnceSink:
    def test_dedups_by_rid(self):
        flow = Flow("eos")
        src = flow.pellet("src", lambda: FnPellet(lambda x: x))
        delivered = []
        sink = flow.sink("sink", delivered.append, exactly_once=True)
        src >> sink
        with flow.session() as s:
            for rid in (1, 2, 1, 3, 2, 1):
                s.inject(src, {"rid": rid, "body": rid * 10})
            out = s.results(timeout=10)
        assert sorted(r["rid"] for r in out) == [1, 2, 3]
        assert sorted(r["rid"] for r in delivered) == [1, 2, 3]

    def test_custom_key_and_state_counts(self):
        flow = Flow("eos2")
        src = flow.pellet("src", lambda: FnPellet(lambda x: x))
        sink = flow.sink("sink", exactly_once=True, key=lambda p: p % 4)
        src >> sink
        with flow.session() as s:
            s.inject_many(src, list(range(8)))
            out = s.results(timeout=10)
            st = s.coordinator.flakes["sink"].state
        assert sorted(p % 4 for p in out) == [0, 1, 2, 3]
        assert st["delivered"] == 4 and st["duplicates"] == 4

    def test_plain_sink_passthrough(self):
        flow = Flow("plain")
        src = flow.pellet("src", lambda: FnPellet(lambda x: x))
        seen = []
        sink = flow.sink("sink", seen.append)
        src >> sink
        with flow.session() as s:
            s.inject_many(src, [1, 1, 2])
            assert sorted(s.results(timeout=10)) == [1, 1, 2]
        assert sorted(seen) == [1, 1, 2]

    def test_key_requires_exactly_once(self):
        from repro import CompositionError
        with pytest.raises(CompositionError):
            Flow("bad").sink("s", key=lambda p: p)


# ---------------------------------------------------------------------------
# tentpole: the serving dataflow
# ---------------------------------------------------------------------------

class TestServingPlane:
    def test_census_continuous_batching(self):
        """All requests complete through a 2-slot decode tier; concurrent
        slots share decode steps (the continuous-batching census)."""
        flow = build_serving_flow(spec=SPEC, n_slots=2, default_budget=4,
                                  seed=0)
        with flow.session() as s:
            s.inject_many("sched", [make_request(i, [1 + i, 2, 3], max_new=4)
                                    for i in range(6)])
            resp = _responses(s.results(timeout=90))
            sched_state = s.coordinator.flakes["sched"].state
            decode = s.coordinator.flakes["decode"]._proto
            assert s.telemetry.array_hits.labels(
                stage="prefill").value >= 6
        assert [r["rid"] for r in resp] == [0, 1, 2, 3, 4, 5]
        assert all(r["n_new"] == 4 for r in resp)
        assert all(r["version"] == 0 for r in resp)
        assert all(r["t_sub"] <= r["t_first"] <= r["t_done"] for r in resp)
        # slot lifecycle closed the loop: every slot freed and re-usable
        assert sched_state["admitted"] == 6 and sched_state["freed"] == 6
        assert sorted(sched_state["free"]) == [0, 1]
        assert decode.n_spliced == 6 and not decode.live.any()
        # census: 6 requests x 3 decode steps each would be 18 solo steps;
        # sharing the slot batch must cut that down
        assert decode.n_steps < 18

    def test_paired_requests_share_steps(self):
        flow = build_serving_flow(spec=SPEC, n_slots=2, default_budget=4,
                                  seed=0)
        with flow.session() as s:
            s.inject_many("sched",
                          [make_request(i, [3, 1], max_new=4)
                           for i in range(2)])
            resp = _responses(s.results(timeout=90))
            steps = s.coordinator.flakes["decode"]._proto.n_steps
        assert len(resp) == 2
        # both slots ride the same step batch: ~3 shared steps, never the
        # 6 a sequential tier would need (small slack for admission skew)
        assert steps <= 4

    def test_kernel_vs_ref_parity_through_dataflow(self):
        """The Pallas-kernel plane and the kernels/ref.py twin must emit
        token-identical responses — parity asserted on stage *outputs*
        after riding the scheduler/prefill/decode dataflow end-to-end."""
        reqs = [make_request(i, [1 + i % 5, 7, 3, 2][: 2 + i % 3],
                             max_new=5, t_sub=float(i)) for i in range(5)]
        outs = {}
        for ref_path in (False, True):
            flow = build_serving_flow(spec=SPEC, n_slots=2,
                                      default_budget=5, seed=3,
                                      ref_path=ref_path)
            with flow.session() as s:
                s.inject_many("sched", [dict(r) for r in reqs])
                outs[ref_path] = _responses(s.results(timeout=90))
        kernel, ref = outs[False], outs[True]
        assert [r["rid"] for r in kernel] == [r["rid"] for r in ref] \
            == [0, 1, 2, 3, 4]
        for rk, rr in zip(kernel, ref):
            assert rk["tokens"] == rr["tokens"], \
                f"rid {rk['rid']}: kernel {rk['tokens']} != ref {rr['tokens']}"

    def test_checkpoint_kill_restore_inflight(self, tmp_path):
        """A consistent cut taken mid-generation restores the KV/slot
        state and finishes every request after a kill."""
        flow = build_serving_flow(spec=SPEC, n_slots=2, default_budget=8,
                                  seed=0)
        path = str(tmp_path / "serving.ckpt")
        s = flow.session().open()
        try:
            s.inject_many("sched",
                          [make_request(i, [2 + i, 5], max_new=8)
                           for i in range(3)])
            decode = s.coordinator.flakes["decode"]._proto
            assert wait_until(lambda: decode.live.any(), timeout=60)
            s.checkpoint(path)
        finally:
            pre_kill = _responses([m.payload for m in s.coordinator.outputs])
            s.close()   # kill mid-generation
        restored = Session.restore(path, flow)
        with restored:
            post = _responses(restored.results(timeout=90))
        by_rid = {}
        for r in list(pre_kill) + list(post):
            by_rid.setdefault(r["rid"], []).append(r)
        assert sorted(by_rid) == [0, 1, 2], f"lost requests: {sorted(by_rid)}"
        for rid, rs in by_rid.items():
            for r in rs:
                assert r["n_new"] == 8, (rid, r)
            # deterministic weights: a cross-kill duplicate must agree
            assert len({tuple(r["tokens"]) for r in rs}) == 1

    def test_hot_swap_zero_loss_version_tags(self):
        """Live weight hot-swap mid-stream: every request answered exactly
        once; completions before the swap tag version 0, after it version
        1; the in-flight generation crosses the swap intact."""
        flow = build_serving_flow(spec=SPEC, n_slots=2, default_budget=3,
                                  seed=0, version=0)
        with flow.session() as s:
            coord = s.coordinator
            # wave 1 completes under v0
            s.inject_many("sched", [make_request(i, [1 + i, 2], max_new=3)
                                    for i in range(2)])
            assert wait_until(
                lambda: len(_responses(
                    [m.payload for m in coord.outputs])) >= 2, timeout=60)
            # a long-running generation to carry across the swap
            s.inject("sched", make_request(10, [3, 4], max_new=12))
            decode = coord.flakes["decode"]._proto
            assert wait_until(lambda: decode.live.any(), timeout=60)
            summary = s.apply(swapped_flow(flow, seed=1, version=1))
            assert sorted(summary["swapped"]) == ["decode", "prefill"]
            # wave 2 completes under v1
            s.inject_many("sched",
                          [make_request(20 + i, [5, 1 + i], max_new=3)
                           for i in range(2)])
            resp = _responses(s.results(timeout=90))
        versions = {r["rid"]: r["version"] for r in resp}
        assert sorted(versions) == [0, 1, 10, 20, 21], \
            f"requests lost across hot-swap: {sorted(versions)}"
        assert len(resp) == 5          # deduped: exactly one response each
        assert versions[0] == 0 and versions[1] == 0
        assert versions[20] == 1 and versions[21] == 1
        carried = next(r for r in resp if r["rid"] == 10)
        # the mid-flight generation crossed the swap without restarting
        assert carried["n_new"] == 12
        assert carried["version"] == 1

    def test_scheduler_rejects_replayed_admission(self):
        sched = Scheduler(n_slots=2, max_prompt=4, max_len=16)
        state = sched.initial_state()

        class _M:
            def __init__(self, p):
                self.payload = p

            def is_data(self):
                return True

        out = []
        req = make_request(1, [1, 2], max_new=2)
        sched.compute([_M(req), _M(dict(req))], out.append, state)
        assert len(out) == 1 and state["rejected"] == 1
