"""Telemetry plane: metrics registry, tracing, events, export surface.

Covers the tentpole guarantees — histogram percentile math, exact census
reconciliation between the metrics plane and an injected-message count on a
live multi-host flow, Prometheus text that parses cleanly, trace contexts
surviving ArrayBatch stacking / live migration / checkpoint-restore, and a
totally ordered event bus under concurrent transactions.  Plus the
satellites: ``inject_many(stacked=True)``, the migration EWMA/histogram
reset regression, and a loose in-process overhead guard (the strict 5%
number lives in ``benchmarks/bench_engine.py``).
"""
import json
import threading
import time

import numpy as np
import pytest

from conftest import wait_until
from repro import ClusterSpec
from repro.api import Flow
from repro.core import (ArrayBatch, Coordinator, FloeGraph, FnPellet,
                        Message)
from repro.telemetry import (LATENCY_BUCKETS, EventBus, MetricsRegistry,
                             Telemetry, Tracer, TRACE_KEY, make_context,
                             parse_prometheus, render_prometheus, trace_of)


def chain_flow(n=3, fn=None, sequential=True):
    flow = Flow("chain")
    stages = []
    for i in range(n):
        f = fn or (lambda x: x)
        stages.append(flow.pellet(f"p{i}", (lambda f=f: FnPellet(
            f, sequential=sequential))))
        if i:
            stages[i - 1] >> stages[i]
    return flow, stages


# ---------------------------------------------------------------------------
# registry: histogram math, labels, prometheus round-trip
# ---------------------------------------------------------------------------

def test_histogram_percentiles_uniform():
    r = MetricsRegistry()
    fam = r.histogram("lat", "latency", ())
    h = fam.labels()
    # uniform samples across [0, 0.1): percentiles land in the right bucket
    for i in range(1000):
        h.observe(i / 10000.0)
    snap = h.snapshot()
    assert snap["count"] == 1000
    assert snap["sum"] == pytest.approx(sum(i / 10000.0 for i in range(1000)))
    # bucket-interpolated estimates: within one bucket width of the truth
    assert h.percentile(0.50) == pytest.approx(0.05, abs=0.026)
    assert h.percentile(0.95) == pytest.approx(0.095, abs=0.026)
    assert h.percentile(0.99) == pytest.approx(0.099, abs=0.026)


def test_histogram_weighted_observe_equals_repeated():
    r = MetricsRegistry()
    a = r.histogram("a", "h", ()).labels()
    b = r.histogram("b", "h", ()).labels()
    for v in (0.001, 0.02, 0.3):
        for _ in range(7):
            a.observe(v)
        b.observe(v, n=7)                 # one weighted call per dispatch
    sa, sb = a.snapshot(), b.snapshot()
    assert sa["count"] == sb["count"] == 21
    assert sa["buckets"] == sb["buckets"]
    assert sa["sum"] == pytest.approx(sb["sum"])
    assert a.percentile(0.5) == b.percentile(0.5)


def test_histogram_reset_and_empty_percentile():
    h = MetricsRegistry().histogram("x", "h", ()).labels()
    assert h.percentile(0.99) == 0.0      # empty: defined, not NaN
    h.observe(0.5, n=10)
    assert h.percentile(0.5) > 0.0
    h.reset()
    assert h.snapshot()["count"] == 0 and h.percentile(0.5) == 0.0


def test_histogram_windowed_percentile_since():
    """Frame differencing: the delta percentile reflects only samples
    observed after the snapshot, while the cumulative view stays
    polluted by history — the whole point of the windowed tail signal."""
    r = MetricsRegistry()
    h = r.histogram("lat", "latency", ()).labels()
    for _ in range(100):
        h.observe(0.001)                    # fast era
    base = h.window_state()
    for _ in range(100):
        h.observe(0.08)                     # slow era after the snapshot
    assert h.percentile_since(base, 0.95) == pytest.approx(0.08, abs=0.03)
    assert h.percentile(0.95) < h.percentile_since(base, 0.95)
    # empty delta is defined (0.0), a reset since the baseline is the
    # rebase sentinel (-1.0), never a bogus percentile
    assert h.percentile_since(h.window_state(), 0.95) == 0.0
    h.reset()
    assert h.percentile_since(base, 0.95) == -1.0


def test_windowed_queue_wait_unbreaches_after_burst():
    """A burst breaches the cumulative p95 forever; the windowed view
    decays once the recent tail recovers (what TailLatencySLO keys on)."""
    tele = Telemetry(tail_window_s=0.01)
    qw = tele.queue_wait.labels(stage="s")
    for _ in range(50):
        qw.observe(0.5)                     # the burst
    first = tele.windowed_queue_wait_p95("s")
    assert first > 0.1                      # startup: cumulative view
    time.sleep(0.02)
    tele.windowed_queue_wait_p95("s")       # rotate a frame past the burst
    time.sleep(0.02)
    for _ in range(200):
        qw.observe(0.001)                   # recovered tail
    w = tele.windowed_queue_wait_p95("s")
    assert w < 0.1                          # windowed signal un-breached
    assert qw.percentile(0.95) > 0.1        # cumulative never does
    assert tele.stage_percentiles("s")["queue_wait_p95_window"] == \
        pytest.approx(w, rel=0.5)


def test_windowed_queue_wait_rebases_on_histogram_reset():
    """A reset under the frames (migration/replace without reset_stage)
    must rebase, not emit the -1.0 sentinel to strategies."""
    tele = Telemetry(tail_window_s=0.01)
    qw = tele.queue_wait.labels(stage="s")
    for _ in range(10):
        qw.observe(0.2)
    tele.windowed_queue_wait_p95("s")
    qw.reset()                              # frames now ahead of the counts
    time.sleep(0.02)
    for _ in range(10):
        qw.observe(0.001)
    assert tele.windowed_queue_wait_p95("s") >= 0.0
    # reset_stage drops the frames with the counts
    tele.reset_stage("s")
    assert tele.windowed_queue_wait_p95("s") == 0.0


def test_percentile_overflow_bucket_floors_to_last_bound():
    h = MetricsRegistry().histogram("x", "h", ()).labels()
    h.observe(99.0, n=4)                  # beyond every finite bucket
    assert h.percentile(0.5) == LATENCY_BUCKETS[-1]


def test_counter_gauge_labels_and_snapshot():
    r = MetricsRegistry()
    c = r.counter("hits", "h", ("stage",))
    c.labels(stage="a").inc()
    c.labels(stage="a").inc(4)
    c.labels(stage="b").inc()
    g = r.gauge("depth", "d", ("stage",))
    g.labels(stage="a").set(17)
    snap = r.snapshot()
    by_stage = {s["labels"]["stage"]: s["value"]
                for s in snap["hits"]["samples"]}
    assert by_stage == {"a": 5, "b": 1}
    assert snap["depth"]["samples"][0]["value"] == 17


def test_prometheus_render_parse_round_trip():
    r = MetricsRegistry()
    r.counter("floe_rows_total", "Rows.", ("stage",)).labels(
        stage='we"ird\\x').inc(3)
    r.gauge("floe_depth", "Depth.", ()).labels().set(2.5)
    h = r.histogram("floe_lat_seconds", "Latency.", ("stage",)).labels(
        stage="a")
    h.observe(0.003, n=5)
    h.observe(2.0)
    text = render_prometheus(r)
    assert "# HELP floe_rows_total Rows." in text
    assert "# TYPE floe_lat_seconds histogram" in text
    series = parse_prometheus(text)
    assert series["floe_rows_total"][0] == ({"stage": 'we"ird\\x'}, 3.0)
    assert series["floe_depth"][0][1] == 2.5
    count = dict((tuple(sorted(l.items())), v)
                 for l, v in series["floe_lat_seconds_count"])
    assert count[(("stage", "a"),)] == 6.0
    # cumulative buckets: the +Inf bucket equals the count
    inf = [v for l, v in series["floe_lat_seconds_bucket"]
           if l.get("le") == "+Inf"]
    assert inf == [6.0]


def test_collector_failures_are_contained():
    r = MetricsRegistry()
    r.counter("ok_total", "ok", ()).labels().inc()
    r.register_collector(lambda: (_ for _ in ()).throw(RuntimeError("boom")))
    text = render_prometheus(r)          # a broken collector never breaks
    assert "ok_total 1" in text          # the scrape


# ---------------------------------------------------------------------------
# event bus
# ---------------------------------------------------------------------------

def test_event_bus_total_order_under_concurrency():
    bus = EventBus()
    n_threads, per = 8, 200

    def worker(i):
        for j in range(per):
            bus.emit("tick", thread=i, j=j)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    recs = bus.records()
    seqs = [r["seq"] for r in recs]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    assert bus.last_seq == n_threads * per
    # per-thread FIFO survives the interleave
    for i in range(n_threads):
        js = [r["j"] for r in recs if r["thread"] == i]
        assert js == list(range(per))


def test_event_bus_subscribe_filter_jsonl():
    bus = EventBus()
    seen = []
    unsub = bus.subscribe(seen.append)
    bus.emit("a", x=1)
    bus.emit("b", x=2)
    unsub()
    bus.emit("a", x=3)
    assert [r["kind"] for r in seen] == ["a", "b"]
    assert [r["x"] for r in bus.records("a")] == [1, 3]
    assert [r for r in bus.records(since_seq=2)][0]["x"] == 3
    for line in bus.to_jsonl().splitlines():
        rec = json.loads(line)            # every line is valid JSON
        assert {"seq", "ts", "kind"} <= set(rec)


# ---------------------------------------------------------------------------
# live engine: census reconciliation, stats surface, events
# ---------------------------------------------------------------------------

def test_metrics_census_reconciles_on_multihost_flow():
    """Acceptance criterion: on a live multi-host flow, per-stage service
    and queue-wait histogram counts equal the injected-message census
    exactly — no samples lost, none double-counted through batching."""
    n = 500
    flow, (p0, p1, p2) = chain_flow(3)
    with flow.session(cluster=ClusterSpec(hosts=2, cores_per_host=8)) as s:
        s.inject_many(p0, list(range(n)))
        assert len(s.results()) == n and not s.errors
        tele = s.telemetry
        assert tele.injected.labels().value == n
        for stage in ("p0", "p1", "p2"):
            svc = tele.service_time.labels(stage=stage).snapshot()
            qw = tele.queue_wait.labels(stage=stage).snapshot()
            assert svc["count"] == n, (stage, svc["count"])
            assert qw["count"] == n, (stage, qw["count"])
        # the scrape agrees and parses cleanly
        series = parse_prometheus(s.prometheus())
        counts = {l["stage"]: v
                  for l, v in series["floe_stage_service_seconds_count"]}
        assert counts == {"p0": float(n), "p1": float(n), "p2": float(n)}
        processed = {l["stage"]: v
                     for l, v in series["floe_stage_processed_total"]}
        assert processed == counts
        hosts = {l["host"] for l, v in series["floe_host_cores_total"]}
        assert hosts == {"h0", "h1"}


def test_stats_surface_has_percentiles_and_legacy_keys():
    flow, (p0, p1) = chain_flow(2)
    with flow.session() as s:
        s.inject_many(p0, list(range(50)))
        s.results()
        st = s.describe()["stages"]["p0"]
        for k in ("queue", "arrived", "processed", "emitted", "avg_latency",
                  "cores", "batch_max", "host", "version",
                  "service_p50", "service_p95", "service_p99",
                  "queue_wait_p95"):
            assert k in st, k
        assert st["arrived"] == 50
        assert st["service_p95"] >= st["service_p50"] > 0.0
        # session.metrics() mirrors the same counts
        m = s.metrics()
        svc = [x for x in m["floe_stage_service_seconds"]["samples"]
               if x["labels"]["stage"] == "p0"]
        assert svc[0]["hist"]["count"] == 50


def test_telemetry_disabled_keeps_legacy_stats_shape():
    flow, (p0, p1) = chain_flow(2)
    with flow.session(telemetry=False) as s:
        s.inject_many(p0, list(range(20)))
        s.results()
        st = s.describe()["stages"]["p0"]
        assert st["arrived"] == 20
        assert "service_p95" not in st    # percentiles need the plane on
        assert s.telemetry.enabled is False
        assert parse_prometheus(s.prometheus()) == {}


def test_error_counter_and_event():
    flow = Flow("err")
    bad = flow.pellet("bad", lambda: FnPellet(
        lambda x: 1 / 0 if x == 3 else x, sequential=True))
    with flow.session() as s:
        s.inject_many(bad, list(range(6)))
        s.results()
        assert wait_until(
            lambda: s.telemetry.errors.labels(stage="bad").value == 1)
        evs = s.events("error")
        assert len(evs) == 1 and evs[0]["flake"] == "bad"
        assert "ZeroDivisionError" in evs[0]["error"]


def test_recomposition_and_elasticity_events_on_bus():
    flow, (p0, p1) = chain_flow(2)
    with flow.session() as s:
        s.inject_many(p0, list(range(10)))
        s.results()
        with s.recompose() as tx:
            tx.scale("p1", cores=3)
        evs = s.events("transaction")
        assert len(evs) == 1 and evs[0]["scaled"] == {"p1": 3}
        # seq ordering spans kinds: the bus is one totally ordered stream
        all_seqs = [r["seq"] for r in s.events()]
        assert all_seqs == sorted(all_seqs)


def test_cluster_ledger_mirrors_onto_bus():
    flow, (p0, p1) = chain_flow(2)
    with flow.session(cluster=ClusterSpec(hosts=2, cores_per_host=8)) as s:
        s.inject_many(p0, list(range(10)))
        s.results()
        src = s.cluster.host_of("p1").name
        s.migrate(p1, "h1" if src == "h0" else "h0")
        migs = s.events("migration")
        assert len(migs) == 1 and migs[0]["flake"] == "p1"
        assert {migs[0]["src"], migs[0]["dst"]} == {"h0", "h1"}
        assert any(e["cluster_event"] == "migrate"
                   for e in s.events("cluster"))


# ---------------------------------------------------------------------------
# migration resets stale latency state (satellite bugfix regression)
# ---------------------------------------------------------------------------

def test_migration_resets_ewma_and_histograms():
    """Regression: migrated flakes kept the old host's EWMA avg_latency and
    histogram samples, poisoning the adaptation controller's view (and the
    cold-start batch guard) on the new core budget."""
    flow, (p0, p1) = chain_flow(2, fn=lambda x: (time.sleep(0.001), x)[1])
    with flow.session(cluster=ClusterSpec(hosts=2, cores_per_host=8)) as s:
        s.inject_many(p0, list(range(50)))
        s.results()
        flake = s.coordinator.flakes["p1"]
        assert flake.stats.avg_latency > 0.0
        assert s.telemetry.service_time.labels(
            stage="p1").snapshot()["count"] == 50
        src = s.cluster.host_of("p1").name
        s.migrate(p1, "h1" if src == "h0" else "h0")
        flake = s.coordinator.flakes["p1"]
        assert flake.stats.avg_latency == 0.0           # EWMA reset
        assert s.telemetry.service_time.labels(
            stage="p1").snapshot()["count"] == 0        # histogram reset
        # counters survive: the census is cumulative across the move
        assert flake.stats.processed == 50
        s.inject_many(p0, list(range(10)))
        s.results()
        assert flake.stats.avg_latency > 0.0            # re-learns fresh


# ---------------------------------------------------------------------------
# tracing: ArrayBatch stacking, migration, checkpoint/restore
# ---------------------------------------------------------------------------

def test_traces_span_every_hop():
    flow, (p0, p1, p2) = chain_flow(3)
    with flow.session(trace_sample=1.0) as s:
        s.inject_many(p0, list(range(20)))
        s.results()
        tids = s.trace()
        assert len(tids) == 20
        for tid in tids:
            spans = s.trace(tid)
            assert [sp["stage"] for sp in spans] == ["p0", "p1", "p2"]
            assert all(sp["t_end"] >= sp["t_start"] for sp in spans)
            # hops are causally ordered
            assert all(a["t_start"] <= b["t_end"]
                       for a, b in zip(spans, spans[1:]))


def test_trace_sampling_fraction():
    flow, (p0,) = chain_flow(1)
    with flow.session(trace_sample=0.25) as s:
        s.inject_many(p0, list(range(400)))
        s.results()
        assert 40 <= len(s.trace()) <= 180   # ~100 expected, seeded RNG


def test_traces_survive_arraybatch_stacking_and_slicing():
    """Trace contexts ride the carrier's sidecar: stacked at the source,
    sliced on hash-split, restored on unstack — every hop still spans."""
    n = 64
    g = FloeGraph("tr")
    g.add("a", lambda: FnPellet(lambda X: np.asarray(X) + 1.0,
                                vectorized=True, sequential=True),
          batch_max=32, batch_array=True)
    g.add("b", lambda: FnPellet(lambda X: np.asarray(X) * 2.0,
                                vectorized=True, sequential=True),
          batch_max=32, batch_array=True)
    g.connect("a", "b")
    coord = Coordinator(g, trace_sample=1.0).start()
    try:
        coord.flakes["a"].pause()
        coord.inject_many("a", [float(i) for i in range(n)], stacked=True)
        coord.flakes["a"].resume()
        assert coord.run_until_quiescent(timeout=60)
        out = sorted(float(m.payload) for m in coord.drain_outputs()
                     if m.is_data())
        assert out == [(i + 1.0) * 2.0 for i in range(n)]
        tracer = coord.telemetry.tracer
        tids = tracer.trace_ids()
        assert len(tids) == n
        rows = {"a": 0, "b": 0}
        for tid in tids:
            spans = tracer.spans(tid)
            assert [sp["stage"] for sp in spans] == ["a", "b"]
            for sp in spans:
                rows[sp["stage"]] += sp["rows"]
        assert rows == {"a": n, "b": n}  # row-weighted spans: exact census
        # the carriers really were shared: far fewer spans' dispatches
        # than messages is already asserted by the array-path suite; here
        # we check the sidecar survived a real stack/unstack cycle
        assert coord.telemetry.stacked_injections.labels().value == 1
    finally:
        coord.stop()


def test_traces_survive_migration_across_hosts():
    flow, (p0, p1, p2) = chain_flow(3)
    with flow.session(cluster=ClusterSpec(hosts=2, cores_per_host=8),
                      trace_sample=1.0) as s:
        s.coordinator.flakes["p1"].pause()
        s.inject_many(p0, list(range(30)))
        assert wait_until(
            lambda: s.coordinator.flakes["p1"].queue_length() == 30)
        dst = "h1" if s.cluster.host_of("p1").name == "h0" else "h0"
        s.migrate(p1, dst)                # traced backlog moves with it
        s.coordinator.flakes["p1"].resume()
        assert len(s.results()) == 30
        tids = s.trace()
        assert len(tids) == 30
        for tid in tids:
            spans = s.trace(tid)
            assert [sp["stage"] for sp in spans] == ["p0", "p1", "p2"]
            p1_span = spans[1]
            assert p1_span["host"] == dst   # span names the post-move host


def test_traces_survive_checkpoint_restore(tmp_path):
    path = str(tmp_path / "floe.ckpt")
    flow, (p0, p1) = chain_flow(2)
    with flow.session(trace_sample=1.0) as s:
        s.coordinator.flakes["p1"].pause()
        s.inject_many(p0, list(range(12)))
        assert wait_until(
            lambda: s.coordinator.flakes["p1"].queue_length() == 12)
        parked = [trace_of(m.meta) for m in
                  s.coordinator.flakes["p1"].inputs["in"]._q]
        old_ids = {c["id"] for c in parked if c}
        assert len(old_ids) == 12
        s.checkpoint(path)
    flow2, _ = chain_flow(2)
    with flow2.session(trace_sample=1.0).open() as s2:
        from repro.checkpoint import restore_floe_graph
        restore_floe_graph(s2.coordinator, path)
        assert len(s2.results()) == 12
        # the restored flow finishes the ORIGINAL traces: p1 spans carry
        # the checkpointed ids, not freshly minted ones
        recorded = set(s2.trace())
        assert old_ids <= recorded
        for tid in old_ids:
            assert [sp["stage"] for sp in s2.trace(tid)] == ["p1"]


def test_trace_context_helpers():
    assert trace_of(None) is None and trace_of({}) is None
    ctx = make_context()
    assert trace_of({TRACE_KEY: ctx}) is ctx
    t = Tracer(sample=0.0)
    assert not t.active and t.maybe_trace() is None
    t = Tracer(sample=1.0, max_traces=4)
    for _ in range(8):
        ctx = t.maybe_trace()
        t.record_span(ctx, stage="s", t_start=0.0, t_end=1.0)
    assert len(t.trace_ids()) == 4        # LRU-bounded


# ---------------------------------------------------------------------------
# stacked injection (satellite)
# ---------------------------------------------------------------------------

def test_inject_many_stacked_builds_one_carrier():
    got = []
    g = FloeGraph("stk")
    g.add("v", lambda: FnPellet(
        lambda X: (got.append(np.asarray(X).shape), np.asarray(X))[1],
        vectorized=True, sequential=True),
        batch_max=128, batch_array=True)
    coord = Coordinator(g).start()
    try:
        coord.flakes["v"].pause()
        coord.inject_many("v", [float(i) for i in range(64)], stacked=True)
        assert coord.flakes["v"].queue_length() == 64   # rows accounted
        # ONE entry in the channel: the carrier was built at the source
        assert len(coord.flakes["v"].inputs["in"]._q) == 1
        coord.flakes["v"].resume()
        assert coord.run_until_quiescent(timeout=60)
        out = [m for m in coord.drain_outputs() if m.is_data()]
        assert len(out) == 64
        assert got == [(64,)]             # one vectorized call, all rows
        assert coord.telemetry.stacked_injections.labels().value == 1
        assert coord.telemetry.injected.labels().value == 64
    finally:
        coord.stop()


def test_inject_many_stacked_ragged_falls_back():
    flow, (p0,) = chain_flow(1)
    with flow.session() as s:
        payloads = [np.zeros((2,)), np.zeros((3,)), "x"]   # unstackable
        s.inject_many(p0, payloads, stacked=True)
        assert len(s.results()) == 3
        assert s.telemetry.stacked_injections.labels().value == 0
        assert s.telemetry.injected.labels().value == 3


def test_inject_many_stacked_respects_keys():
    g = FloeGraph("stkk")
    g.add("v", lambda: FnPellet(lambda X: np.asarray(X), vectorized=True,
                                sequential=True),
          batch_max=128, batch_array=True)
    coord = Coordinator(g).start()
    try:
        coord.inject_many("v", [float(i) for i in range(8)],
                          keys=[i % 2 for i in range(8)], stacked=True)
        assert coord.run_until_quiescent(timeout=60)
        out = [m for m in coord.drain_outputs() if m.is_data()]
        assert sorted(m.key for m in out) == [0] * 4 + [1] * 4
    finally:
        coord.stop()


# ---------------------------------------------------------------------------
# array-path + backpressure observability
# ---------------------------------------------------------------------------

def test_array_hit_and_degrade_counters():
    n = 96
    g = FloeGraph("deg")
    g.add("v", lambda: FnPellet(lambda X: np.asarray(X) + 1.0,
                                vectorized=True, sequential=True),
          batch_max=32, batch_array=True)
    g.add("scalar", lambda: FnPellet(lambda x: x, sequential=True))
    g.connect("v", "scalar")
    coord = Coordinator(g).start()
    try:
        coord.flakes["v"].pause()
        coord.inject_many("v", [float(i) for i in range(n)], stacked=True)
        coord.flakes["v"].resume()
        assert coord.run_until_quiescent(timeout=60)
        assert len([m for m in coord.drain_outputs() if m.is_data()]) == n
        tele = coord.telemetry
        assert tele.array_hits.labels(stage="v").value == n
        # scalar consumer forced carrier unstack: degradations recorded
        assert tele.degradations.labels(stage="scalar").value >= 1
    finally:
        coord.stop()


def test_backpressure_stall_counter():
    g = FloeGraph("bp")
    g.add("slow", lambda: FnPellet(
        lambda x: (time.sleep(0.01), x)[1], sequential=True))
    coord = Coordinator(g, channel_capacity=4).start()
    try:
        for i in range(40):
            coord.inject("slow", i)
        assert coord.run_until_quiescent(timeout=60)
        assert coord.telemetry.stalls.labels(
            stage="slow").value > 0
    finally:
        coord.stop()


# ---------------------------------------------------------------------------
# overhead guard (loose in-process check; strict 5% lives in bench_engine)
# ---------------------------------------------------------------------------

def test_telemetry_overhead_is_bounded():
    def run(telemetry):
        flow, stages = chain_flow(4)
        with flow.session(telemetry=telemetry) as s:
            t0 = time.perf_counter()
            s.inject_many(stages[0], list(range(2000)))
            assert len(s.results()) == 2000
            return time.perf_counter() - t0

    run(True), run(False)                 # warm both paths
    on = min(run(True) for _ in range(3))
    off = min(run(False) for _ in range(3))
    # generous in-process bound to stay CI-stable; the 5% acceptance
    # number is measured by benchmarks/bench_engine.py --telemetry
    assert on < off * 1.5 + 0.05, (on, off)
