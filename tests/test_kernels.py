"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


def maxerr(a, b):
    return float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                 b.astype(jnp.float32))))


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,Hkv,hd", [
    (1, 64, 2, 2, 64),      # MHA
    (2, 128, 4, 2, 64),     # GQA 2:1
    (1, 96, 6, 2, 32),      # ragged seq (pad path), GQA 3:1
    (2, 64, 5, 5, 24),      # odd heads + unaligned hd (pad path)
    (1, 256, 8, 1, 64),     # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, Hkv, hd, dtype):
    q = rand(0, (B, S, H, hd), dtype)
    k = rand(1, (B, S, Hkv, hd), dtype)
    v = rand(2, (B, S, Hkv, hd), dtype)
    got = ops.flash_attention_op(q, k, v, causal=True, block_q=32,
                                 block_k=32, interpret=True)
    want = ref.attention(q, k, v, causal=True)
    assert maxerr(got, want) < TOL[dtype]


@pytest.mark.parametrize("window", [8, 32])
def test_flash_attention_sliding_window(window):
    q = rand(0, (2, 128, 4, 64), jnp.bfloat16)
    k = rand(1, (2, 128, 2, 64), jnp.bfloat16)
    v = rand(2, (2, 128, 2, 64), jnp.bfloat16)
    got = ops.flash_attention_op(q, k, v, causal=True, window=window,
                                 block_q=32, block_k=32, interpret=True)
    want = ref.attention(q, k, v, causal=True, window=window)
    assert maxerr(got, want) < TOL[jnp.bfloat16]


def test_flash_attention_non_causal():
    q = rand(0, (1, 64, 4, 64), jnp.float32)
    k = rand(1, (1, 64, 4, 64), jnp.float32)
    v = rand(2, (1, 64, 4, 64), jnp.float32)
    got = ops.flash_attention_op(q, k, v, causal=False, block_q=32,
                                 block_k=32, interpret=True)
    want = ref.attention(q, k, v, causal=False)
    assert maxerr(got, want) < TOL[jnp.float32]


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,Hkv,hd", [
    (2, 128, 4, 2, 64),
    (3, 96, 5, 5, 24),
    (1, 256, 8, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, S, H, Hkv, hd, dtype):
    q = rand(0, (B, H, hd), dtype)
    k = rand(1, (B, S, Hkv, hd), dtype)
    v = rand(2, (B, S, Hkv, hd), dtype)
    lengths = jnp.asarray([(7 * (i + 3)) % S + 1 for i in range(B)],
                          jnp.int32)
    got = ops.decode_attention_op(q, k, v, lengths, block_k=32,
                                  interpret=True)
    want = ref.decode_attention(q, k, v, lengths)
    assert maxerr(got, want) < TOL[dtype]


def test_decode_attention_window():
    B, S = 2, 128
    q = rand(0, (B, 4, 64), jnp.float32)
    k = rand(1, (B, S, 2, 64), jnp.float32)
    v = rand(2, (B, S, 2, 64), jnp.float32)
    lengths = jnp.array([100, 64], jnp.int32)
    got = ops.decode_attention_op(q, k, v, lengths, window=16, block_k=32,
                                  interpret=True)
    want = ref.decode_attention(q, k, v, lengths, window=16)
    assert maxerr(got, want) < TOL[jnp.float32]


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,di,N", [
    (1, 16, 32, 8),
    (2, 64, 128, 16),
    (2, 33, 64, 4),     # odd seq length
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_sweep(B, S, di, N, dtype):
    x = rand(0, (B, S, di), dtype)
    dt = jax.nn.softplus(rand(1, (B, S, di), jnp.float32)).astype(dtype)
    A = -jnp.exp(rand(2, (di, N), jnp.float32) * 0.1)
    B_ = rand(3, (B, S, N), dtype)
    C_ = rand(4, (B, S, N), dtype)
    y, h = ops.ssm_scan_op(x, dt, A, B_, C_, block_d=32, interpret=True)
    yr, hr = ref.ssm_scan(x, dt, A, B_, C_)
    assert maxerr(y, yr) < TOL[dtype] * 4   # recurrence accumulates error
    assert maxerr(h, hr) < TOL[dtype] * 4


def test_ssm_scan_with_initial_state():
    B, S, di, N = 2, 16, 32, 8
    x = rand(0, (B, S, di), jnp.float32)
    dt = jax.nn.softplus(rand(1, (B, S, di), jnp.float32))
    A = -jnp.exp(rand(2, (di, N), jnp.float32) * 0.1)
    B_ = rand(3, (B, S, N), jnp.float32)
    C_ = rand(4, (B, S, N), jnp.float32)
    h0 = rand(5, (B, di, N), jnp.float32)
    y, h = ops.ssm_scan_op(x, dt, A, B_, C_, h0, block_d=32, interpret=True)
    yr, hr = ref.ssm_scan(x, dt, A, B_, C_, h0)
    assert maxerr(y, yr) < 1e-4
    # continuation property: scanning halves sequentially == full scan
    y1, h1 = ops.ssm_scan_op(x[:, :8], dt[:, :8], A, B_[:, :8], C_[:, :8],
                             h0, block_d=32, interpret=True)
    y2, h2 = ops.ssm_scan_op(x[:, 8:], dt[:, 8:], A, B_[:, 8:], C_[:, 8:],
                             h1, block_d=32, interpret=True)
    assert maxerr(jnp.concatenate([y1, y2], axis=1), yr) < 1e-4
    assert maxerr(h2, hr) < 1e-4


# ---------------------------------------------------------------------------
# MoE dispatch/combine (dynamic port mapping)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,D,E,K,C", [
    (32, 16, 4, 1, 16),
    (64, 32, 4, 2, 48),
    (128, 64, 8, 2, 32),   # tight capacity -> drops exercised
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_dispatch_combine_sweep(T, D, E, K, C, dtype):
    x = rand(0, (T, D), dtype)
    logits = rand(1, (T, E), jnp.float32)
    w, e, pos, keep, src, valid = ops.route(logits, K, C)
    buf = ops.moe_dispatch_op(x, src, valid, interpret=True)
    bref = ref.moe_gather_dispatch(x, src, valid)
    assert maxerr(buf, bref) == 0.0          # pure data movement: exact
    y = ops.moe_combine_op(buf, e, pos, w, keep, interpret=True)
    yref = ref.moe_gather_combine(bref, e, pos, w, keep)
    assert maxerr(y, yref) < TOL[dtype]


def test_moe_ffn_pallas_matches_model_moe():
    """Kernel-backed MoE FFN == the model's jnp moe_ffn (same routing)."""
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.models.mlp import capacity, moe_ffn
    T, D, E, K, F = 64, 32, 4, 2, 48
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=D,
                      n_heads=2, n_kv_heads=2, d_ff=F, vocab_size=64,
                      moe=MoEConfig(n_experts=E, top_k=K, d_expert=F))
    params = {
        "router": rand(0, (D, E), jnp.float32),
        "w_gate": rand(1, (E, D, F), jnp.float32),
        "w_up": rand(2, (E, D, F), jnp.float32),
        "w_down": rand(3, (E, F, D), jnp.float32),
    }
    x = rand(4, (T, D), jnp.float32)
    want, _ = moe_ffn(params, x, cfg)
    cap = capacity(T, cfg.moe)
    got = ops.moe_ffn_pallas(x, params["router"], params["w_gate"],
                             params["w_up"], params["w_down"], K, cap,
                             interpret=True)
    assert maxerr(got, want) < 2e-4


# ---------------------------------------------------------------------------
# cluster distance (array fast-path distance stage)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,D,K", [
    (8, 16, 4),       # tiny, aligned-ish
    (37, 19, 5),      # every dim unaligned (pad paths)
    (256, 32, 12),    # multi-tile batch
])
def test_cluster_distance_sweep(B, D, K):
    x = rand(0, (B, D), jnp.float32)
    c = rand(1, (K, D), jnp.float32)
    got = ops.cluster_distance_op(x, c, block_b=64, interpret=True)
    want = jnp.sum((x[:, None, :] - c[None, :, :]) ** 2, axis=-1)
    assert got.shape == (B, K)
    assert maxerr(got, want) < 1e-3


def test_cluster_distance_nearest_assignment_exact():
    """argmin over the kernel's distances == brute-force nearest centroid."""
    import numpy as np
    rng = np.random.default_rng(3)
    c = rng.normal(size=(6, 24)).astype(np.float32) * 2
    x = c[rng.integers(6, size=100)] + \
        rng.normal(size=(100, 24)).astype(np.float32) * 0.05
    got = jnp.argmin(ops.cluster_distance_op(x, c, interpret=True), axis=1)
    want = jnp.argmin(jnp.sum(
        (jnp.asarray(x)[:, None, :] - jnp.asarray(c)[None, :, :]) ** 2,
        axis=-1), axis=1)
    assert (np.asarray(got) == np.asarray(want)).all()
