"""Dynamic-topology Session API (ISSUE 4): graph-diff recomposition —
vertex add/remove under live load, declarative ``session.apply(flow)``,
checkpoint-integrated sessions, topology versioning, split rebuild."""
import threading
import time

import pytest

from conftest import wait_until
from repro import (ClusterManager, ClusterSpec, Flow, FnPellet, PullPellet,
                   PushPellet, RecompositionError, Session, WindowPellet)
from repro.checkpoint import read_floe_meta


class Tag(PushPellet):
    """Pass-through that labels payloads so the census can see the route."""

    def __init__(self, tag):
        self.tag = tag

    def compute(self, x):
        return (self.tag, x)


class SumWindow(WindowPellet):
    def compute(self, payloads):
        return sum(payloads)


class FlushWindow(WindowPellet):
    """Large window: only a landmark flush ever emits."""
    window = 100

    def compute(self, payloads):
        return ("flush", sorted(payloads))


class Summer(PullPellet):
    def initial_state(self):
        return 0

    def compute(self, messages, emit, state):
        for m in messages:
            if m.is_data():
                state += m.payload
                emit(state)
        return state


def _linear_flow():
    flow = Flow("lin")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x))
    work = flow.pellet("work", lambda: FnPellet(lambda x: x))
    src >> work
    return flow


# ---------------------------------------------------------------------------
# recompose: vertex addition / removal
# ---------------------------------------------------------------------------

def test_recompose_add_stage_and_connect():
    flow = _linear_flow()
    with flow.session() as s:
        with s.recompose() as tx:
            tx.add("tag", lambda: Tag("grafted"))
            tx.connect("work", "tag")
        assert tx.result["added"] == ["tag"]
        assert "tag" in s.coordinator.flakes
        s.inject("src", 7)
        assert s.results() == [("grafted", 7)]


def test_recompose_add_from_stage_handle_carries_annotations():
    flow = _linear_flow()
    scratch = Flow("scratch")
    handle = scratch.pellet("tag", lambda: Tag("h"), cores=3).batch(16)
    with flow.session() as s:
        with s.recompose() as tx:
            tx.add(handle)
            tx.connect("work", "tag")
        flake = s.coordinator.flakes["tag"]
        assert flake.cores == 3
        assert flake.batch_max == 16
        s.inject("src", 1)
        assert s.results() == [("h", 1)]


def test_recompose_remove_stage_releases_cores_and_routes():
    flow = _linear_flow()
    tag = flow.pellet("tag", lambda: Tag("t"), cores=2)
    flow.stages["work"] >> tag
    with flow.session() as s:
        coord = s.coordinator
        container = coord._container_of["tag"]
        held = container.allocated.get("tag", 0)
        assert held == 2
        with s.recompose() as tx:
            tx.remove("tag")
        assert "tag" not in coord.flakes
        assert container.allocated.get("tag", 0) == 0
        assert "tag" not in coord.graph.vertices
        # the dataflow keeps running: work is a sink again
        s.inject("src", 5)
        assert s.results() == [5]


def test_remove_backlog_collect_surfaces_messages_and_credits():
    flow = _linear_flow()
    slow = flow.pellet("slow", lambda: FnPellet(lambda x: x))
    flow.stages["work"] >> slow
    with flow.session() as s:
        s.coordinator.flakes["slow"].pause()   # park backlog in 'slow'
        s.inject_many("src", list(range(20)))

        def parked():
            return s.coordinator.flakes["slow"].queue_length() == 20
        deadline = time.time() + 10
        while not parked() and time.time() < deadline:
            time.sleep(0.01)
        assert parked()
        with s.recompose() as tx:
            tx.remove("slow", backlog="collect")
        backlog = tx.result["backlog"]["slow"]
        assert sorted(m.payload for m in backlog) == list(range(20))
        assert tx.result["removed_backlog"]["slow"] == 20
        # credits released: the engine must go quiescent, not wedge
        assert s.quiesce(10)


def test_remove_backlog_reroute_preserves_messages():
    flow = _linear_flow()
    old = flow.pellet("old", lambda: Tag("old"))
    new = flow.pellet("new", lambda: Tag("new"))
    flow.stages["work"] >> old
    with flow.session() as s:
        s.coordinator.flakes["old"].pause()
        s.inject_many("src", list(range(10)))
        deadline = time.time() + 10
        while s.coordinator.flakes["old"].queue_length() < 10 and \
                time.time() < deadline:
            time.sleep(0.01)
        with s.recompose() as tx:
            tx.remove("old", backlog=("new", "in"))
            tx.connect("work", "new")
        out = s.results()
        assert sorted(x for (_, x) in out) == list(range(10))
        assert all(t == "new" for (t, _) in out)


def test_recompose_add_remove_under_live_load_census():
    """Graft a stage onto a running pipeline, then retire it, while a
    producer thread keeps injecting: every message arrives exactly once
    (zero loss, zero duplication) and per-key FIFO order holds."""
    N, KEYS = 3000, 8

    class KeyedRelay(PushPellet):
        """Pass-through that PRESERVES the routing key on emit, so the
        downstream hash split keeps pinning each key to one worker."""
        sequential = True

        def compute(self, x):
            from repro import KeyedEmit
            return KeyedEmit(x, key=x[0])

    # sequential pellets: per-key FIFO is only contractual without the
    # data-parallel instance pool (same setup as the migration census)
    flow = Flow("live")
    src = flow.pellet("src", KeyedRelay)
    w0 = flow.pellet("w0", lambda: FnPellet(lambda x: x, sequential=True))
    w1 = flow.pellet("w1", lambda: FnPellet(lambda x: x, sequential=True))
    gather = flow.pellet("gather",
                         lambda: FnPellet(lambda x: x, sequential=True))
    src.split("hash") >> w0
    src >> w1
    w0 >> gather
    w1 >> gather
    with flow.session() as s:
        def producer():
            for i in range(N):
                key = i % KEYS
                s.inject("src", (key, i), key=key)
                if i % 400 == 0:
                    time.sleep(0.01)

        t = threading.Thread(target=producer)
        t.start()
        time.sleep(0.02)
        # graft an audit branch mid-stream...
        with s.recompose() as tx:
            tx.add("audit", lambda: Tag("audit"))
            tx.connect("gather", "audit")
        time.sleep(0.05)
        # ...and retire it again; its parked backlog is surfaced, not lost
        with s.recompose() as tx2:
            tx2.remove("audit", backlog="collect")
        t.join()
        out = s.results(timeout=60)
        collected = tx2.result.get("backlog", {}).get("audit", [])
        # normalize: out entries are in sink-collection order; entries that
        # passed through the grafted branch carry the "audit" tag
        seen = [o[1] if isinstance(o, tuple) and o[0] == "audit" else o
                for o in out]
        ids = sorted([x[1] for x in seen]
                     + [m.payload[1] for m in collected])
        assert ids == list(range(N)), (
            f"census mismatch: {len(ids)} messages, "
            f"lost={set(range(N)) - set(ids)}, "
            f"dups={[i for i in ids if ids.count(i) > 1][:5]}")
        # per-key FIFO over the sink order: hash split pins a key to one
        # worker and the grafted/retired branch extends the path without
        # reordering it.  (The collected backlog was pulled out of the
        # stream at removal — it fills id gaps in the census above but has
        # no position in the sink timeline.)
        dropped = {m.payload[1] for m in collected}
        order = {}
        for key, i in seen:
            assert i not in dropped, "collected message also delivered"
            assert order.get(key, -1) < i, f"key {key} reordered at {i}"
            order[key] = i


def test_remove_stage_with_half_gathered_window():
    flow = Flow("win")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x))
    win = flow.pellet("win", lambda: SumWindow(10))
    src >> win
    with flow.session() as s:
        s.inject_many("src", [1, 2, 3])     # half-gathered window
        deadline = time.time() + 10
        while not s.coordinator.flakes["win"]._window_buf and \
                time.time() < deadline:
            time.sleep(0.01)
        assert s.coordinator.flakes["win"]._window_buf
        with s.recompose() as tx:
            tx.remove("win", backlog="collect")
        # the half-gathered messages are surfaced, their credits released
        assert sorted(m.payload for m in tx.result["backlog"]["win"]) == \
            [1, 2, 3]
        assert s.quiesce(10)


def test_remove_upstream_completes_pending_landmark_round():
    """Retiring one of a reducer's feeders (fan-in 2 -> 1) completes a
    half-counted landmark alignment round instead of losing it."""
    flow = Flow("lm")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x))
    b = flow.pellet("b", lambda: FnPellet(lambda x: x))
    win = flow.pellet("win", FlushWindow)
    a >> win
    b >> win
    with flow.session() as s:
        s.inject("a", 1)
        s.inject("b", 2)
        # window-buffered messages hold their credits until a flush, so
        # poll the buffer instead of engine-wide quiescence
        deadline = time.time() + 10
        while len(s.coordinator.flakes["win"]._window_buf) < 2 and \
                time.time() < deadline:
            time.sleep(0.01)
        assert len(s.coordinator.flakes["win"]._window_buf) == 2
        s.inject_landmark("a")              # 1 of 2 copies: swallowed
        time.sleep(0.2)
        assert s.coordinator.flakes["win"]._lm_pending is not None
        with s.recompose() as tx:
            tx.remove("b")
        out = s.results(timeout=20)
        assert ("flush", [1, 2]) in out     # the round completed


def test_recompose_add_invalid_wiring_rolls_back_everything():
    flow = _linear_flow()
    with flow.session() as s:
        v0 = s.coordinator.topology_version
        with pytest.raises(RecompositionError, match="no INPUT port"):
            with s.recompose() as tx:
                tx.add("tag", lambda: Tag("t"))
                tx.connect("work", "tag", dst_port="nope")
        assert s.coordinator.topology_version == v0
        assert "tag" not in s.coordinator.flakes
        assert s.coordinator.core_audit() == {
            c.name: dict(c.allocated)
            for c in s.coordinator.containers if c.allocated}
        s.inject("src", 3)
        assert s.results() == [3]


def test_add_then_remove_same_name_in_one_tx_rejected():
    flow = _linear_flow()
    with flow.session() as s:
        with pytest.raises(RecompositionError, match="both added and"):
            with s.recompose() as tx:
                tx.add("x", lambda: Tag("x"))
                tx.remove("x")


def test_remove_unknown_and_swap_removed_rejected():
    flow = _linear_flow()
    with flow.session() as s:
        with pytest.raises(RecompositionError, match="unknown stage"):
            with s.recompose() as tx:
                tx.remove("ghost")
        with pytest.raises(RecompositionError, match="cannot also be"):
            with s.recompose() as tx:
                tx.remove("work")
                tx.swap("work", lambda: FnPellet(lambda x: x))


def test_grafted_stage_with_elastic_policy_joins_controller():
    flow = _linear_flow()
    scratch = Flow("scratch")
    handle = scratch.pellet("burst", lambda: FnPellet(lambda x: x)).elastic(
        max_cores=4, strategy="dynamic")
    with flow.session() as s:
        assert s.controller is None
        with s.recompose() as tx:
            tx.add(handle)
            tx.connect("work", "burst")
        assert s.controller is not None
        assert "burst" in s.controller.strategies
        with s.recompose() as tx:
            tx.remove("burst")
        assert "burst" not in s.controller.strategies


# ---------------------------------------------------------------------------
# topology version + diff summary
# ---------------------------------------------------------------------------

def test_topology_version_monotonic_and_diff_in_describe():
    flow = _linear_flow()
    with flow.session() as s:
        d = s.describe()
        assert d["topology_version"] == 0
        assert d["last_recomposition"] is None
        with s.recompose() as tx:
            tx.add("tag", lambda: Tag("t"))
            tx.connect("work", "tag")
        d1 = s.describe()
        assert d1["topology_version"] == 1
        assert d1["last_recomposition"]["added"] == ["tag"]
        assert d1["last_recomposition"]["edges_added"] == [
            {"src": "work", "src_port": "out", "dst": "tag",
             "dst_port": "in", "split": "round_robin",
             "transport": "push"}]
        with s.recompose() as tx:
            tx.scale("work", cores=2)
        d2 = s.describe()
        assert d2["topology_version"] == 2
        assert d2["last_recomposition"]["scaled"] == {"work": 2}
        # an aborted transaction must NOT bump the version
        with pytest.raises(RecompositionError):
            with s.recompose() as tx:
                tx.remove("ghost")
        assert s.describe()["topology_version"] == 2


# ---------------------------------------------------------------------------
# declarative session.apply(flow)
# ---------------------------------------------------------------------------

def test_apply_commits_add_remove_rewire_delta_atomically():
    flow = Flow("pipe")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x))
    old = flow.pellet("old", lambda: Tag("old"))
    src >> old
    with flow.session() as s:
        s.inject("src", 1)
        assert s.results() == [("old", 1)]
        nf = s.flow.derive()
        nf.remove("old")
        fresh = nf.pellet("fresh", lambda: Tag("fresh"))
        nf.stages["src"] >> fresh
        summary = s.apply(nf)
        assert summary["added"] == ["fresh"]
        assert summary["removed"] == ["old"]
        assert s.describe()["topology_version"] == 1
        assert s.flow is nf
        s.inject("src", 2)
        assert s.results() == [("fresh", 2)]


def test_apply_under_live_load_census():
    """The acceptance-criteria scenario: one apply() commits an
    add+remove+rewire delta on a running session with zero message loss
    or duplication."""
    N = 2000
    flow = Flow("pipe")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x))
    old = flow.pellet("old", lambda: Tag("old"))
    src >> old
    with flow.session() as s:
        def producer():
            for i in range(N):
                s.inject("src", i)
                if i % 250 == 0:
                    time.sleep(0.01)

        t = threading.Thread(target=producer)
        t.start()
        time.sleep(0.02)
        nf = s.flow.derive()
        nf.remove("old")                      # remove
        fresh = nf.pellet("fresh", lambda: Tag("fresh"))
        nf.stages["src"] >> fresh             # add + rewire
        summary = s.apply(nf, backlog="collect")
        t.join()
        out = s.results(timeout=60)
        ids = [x for (_, x) in out]
        for m in summary.get("backlog", {}).get("old", []):
            ids.append(m.payload)
        assert sorted(ids) == list(range(N)), (
            f"{len(ids)} messages, lost={set(range(N)) - set(ids)}")


def test_apply_noop_commits_nothing():
    flow = _linear_flow()
    with flow.session() as s:
        v0 = s.describe()["topology_version"]
        summary = s.apply(s.flow.derive())
        assert summary == {"changed": False, "noop": True, "version": v0}
        assert s.describe()["topology_version"] == v0
        assert s.describe()["last_recomposition"] is None


def test_apply_invalid_diff_rolls_back_before_any_change():
    flow = _linear_flow()
    with flow.session() as s:
        v0 = s.describe()["topology_version"]
        nf = s.flow.derive()
        # bypass .replace() validation: a factory producing a non-Pellet
        # must be caught by apply itself, before any change
        nf.stages["work"].factory = lambda: 42
        with pytest.raises(RecompositionError, match="expected a Pellet"):
            s.apply(nf)
        assert s.describe()["topology_version"] == v0
        assert s.flow is not nf
        s.inject("src", 9)
        assert s.results() == [9]


def test_apply_same_name_replacement_with_changed_ports():
    """ROADMAP follow-up: a same-name stage whose factory changes the port
    signature is committed as a replacement in ONE transaction — new
    wiring validated against the fresh proto's ports, backlog on the
    surviving input port carried over FIFO."""
    class TwoOut(PushPellet):
        out_ports = ("hi", "lo")

        def compute(self, x):
            return {"hi" if x >= 10 else "lo": x}

    flow = Flow("rep")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x))
    work = flow.pellet("work", lambda: Tag("v1"))
    sink = flow.pellet("sink", lambda: FnPellet(lambda x: ("sunk", x)))
    src >> work
    work >> sink
    with flow.session() as s:
        s.inject("src", 1)
        assert s.results() == [("sunk", ("v1", 1))]
        v0 = s.describe()["topology_version"]
        # park backlog in the stage being replaced: it must survive the
        # swap and be processed by the NEW logic
        s.coordinator.flakes["work"].pause()
        s.inject("src", 3)
        s.inject("src", 42)
        assert wait_until(
            lambda: s.coordinator.flakes["work"].queue_length() == 2)
        nf = s.flow.derive()
        nf.disconnect("src", "work")
        nf.disconnect("work", "sink")
        nf.stages["work"].replace(TwoOut)      # in=(in,), out=(hi, lo)
        nf.stages["src"] >> nf.stages["work"]
        nf.stages["work"]["hi"] >> nf.stages["sink"]
        nf.stages["work"]["lo"] >> nf.stages["sink"]
        summary = s.apply(nf)
        assert summary["replaced"] == ["work"]
        assert summary["swapped"] == []
        assert s.describe()["topology_version"] == v0 + 1
        out = s.results()
        assert sorted(out) == [("sunk", 3), ("sunk", 42)]   # carried FIFO
        s.inject("src", 7)
        s.inject("src", 70)
        assert sorted(s.results()) == [("sunk", 7), ("sunk", 70)]
        assert not s.errors, s.errors[:3]


def test_apply_replacement_preserves_landmark_alignment():
    """A fan-in-2 stage replaced mid-alignment (one landmark copy already
    swallowed) must complete the round when the second copy arrives —
    alignment progress moves to the replacement like it does in
    migration."""
    class TwoOut(PushPellet):
        out_ports = ("x", "y")

        def compute(self, v):
            return {"x": v}

    flow = Flow("lmrep")
    s1 = flow.pellet("s1", lambda: FnPellet(lambda x: x))
    s2 = flow.pellet("s2", lambda: FnPellet(lambda x: x))
    mid = flow.pellet("mid", lambda: FnPellet(lambda x: x))
    sink = flow.pellet("sink", lambda: FnPellet(lambda x: x))
    s1 >> mid
    s2 >> mid
    mid >> sink
    with flow.session() as s:
        s.inject_landmark("s1", tag="w0")   # copy 1 of 2: swallowed at mid
        assert s.quiesce()
        assert s.coordinator.flakes["mid"]._lm_count == 1
        nf = s.flow.derive()
        nf.disconnect("mid", "sink")
        nf.stages["mid"].replace(TwoOut)
        nf.stages["mid"]["x"] >> nf.stages["sink"]
        nf.stages["mid"]["y"] >> nf.stages["sink"]
        assert s.apply(nf)["replaced"] == ["mid"]
        s.inject_landmark("s2", tag="w0")   # copy 2 completes the round
        out = s.drain()
        assert sum(1 for m in out if m.landmark) == 1
        assert not s.errors, s.errors[:3]


def test_apply_replacement_rejects_stale_wiring():
    """Edges still naming a port the replacement proto lacks abort the
    whole transaction before any change."""
    class TwoOut(PushPellet):
        out_ports = ("hi", "lo")

        def compute(self, x):
            return {"hi": x}

    flow = Flow("stale")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x))
    work = flow.pellet("work", lambda: Tag("v1"))
    sink = flow.pellet("sink", lambda: FnPellet(lambda x: x))
    src >> work
    work >> sink
    with flow.session() as s:
        v0 = s.describe()["topology_version"]
        nf = s.flow.derive()
        nf.stages["work"].replace(TwoOut)
        # old edge work["out"] -> sink left in place: invalid for TwoOut
        with pytest.raises(RecompositionError, match="OUTPUT port"):
            s.apply(nf)
        assert s.describe()["topology_version"] == v0
        s.inject("src", 5)
        assert s.results() == [("v1", 5)]   # old logic untouched


def test_apply_swaps_pellet_and_retunes_batch():
    flow = Flow("sw")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x))
    work = flow.pellet("work", lambda: Tag("v1"))
    src >> work
    with flow.session() as s:
        s.inject("src", 1)
        assert s.results() == [("v1", 1)]
        nf = s.flow.derive()
        nf.stages["work"].replace(lambda: Tag("v2"))
        nf.stages["work"].batch(32)
        summary = s.apply(nf)
        assert summary["swapped"] == ["work"]
        assert summary["batch_updated"] == ["work"]
        assert s.coordinator.flakes["work"].batch_max == 32
        s.inject("src", 2)
        assert s.results() == [("v2", 2)]


def test_apply_batch_annotation_removal_reverts_to_default():
    from repro.core.engine import DEFAULT_BATCH_MAX
    flow = Flow("ba")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x))
    work = flow.pellet("work", lambda: FnPellet(lambda x: x)).batch(7)
    src >> work
    with flow.session() as s:
        assert s.coordinator.flakes["work"].batch_max == 7
        nf = s.flow.derive()
        del nf.stages["work"].annotations["batch_max"]
        del nf.stages["work"].annotations["batch_wait_ms"]
        summary = s.apply(nf)
        assert summary["batch_updated"] == ["work"]
        flake = s.coordinator.flakes["work"]
        assert flake.batch_max == DEFAULT_BATCH_MAX
        assert not flake._batch_explicit


def test_last_transaction_does_not_retain_collected_backlog():
    """describe()/the coordinator must not pin collected Messages."""
    flow = _linear_flow()
    tail = flow.pellet("tail", lambda: FnPellet(lambda x: x))
    flow.stages["work"] >> tail
    with flow.session() as s:
        s.coordinator.flakes["tail"].pause()
        s.inject("src", 1)
        deadline = time.time() + 10
        while s.coordinator.flakes["tail"].queue_length() < 1 and \
                time.time() < deadline:
            time.sleep(0.01)
        with s.recompose() as tx:
            tx.remove("tail", backlog="collect")
        assert len(tx.result["backlog"]["tail"]) == 1   # caller gets them
        assert "backlog" not in s.coordinator.last_transaction
        assert s.coordinator.last_transaction["removed_backlog"] == \
            {"tail": 1}


def test_apply_elastic_policy_change_syncs_controller():
    flow = _linear_flow()
    with flow.session() as s:
        assert s.controller is None
        nf = s.flow.derive()
        nf.stages["work"].elastic(max_cores=4)
        summary = s.apply(nf)
        assert summary["elastic_updated"] == ["work"]
        assert s.controller is not None and \
            "work" in s.controller.strategies
        nf2 = s.flow.derive()
        nf2.stages["work"].policy = None
        s.apply(nf2)
        assert "work" not in s.controller.strategies


# ---------------------------------------------------------------------------
# checkpoint-integrated sessions
# ---------------------------------------------------------------------------

def test_checkpoint_kill_restore_roundtrip(tmp_path):
    def build():
        flow = Flow("ck")
        src = flow.pellet("src", lambda: FnPellet(lambda x: x))
        summer = flow.pellet("sum", Summer)
        src >> summer
        return flow

    path = str(tmp_path / "sess.ckpt")
    with build().session() as s:
        s.inject_many("src", [10, 5])
        assert s.quiesce(20)
        s.drain()
        # park two messages mid-pipeline, then snapshot the live session
        s.coordinator.flakes["sum"].pause()
        s.inject("src", 7)
        s.inject("src", 3)
        deadline = time.time() + 10
        while s.coordinator.flakes["sum"].queue_length() < 2 and \
                time.time() < deadline:
            time.sleep(0.01)
        meta = s.checkpoint(path)
        assert meta["flow"] == "ck" and meta["topology_version"] == 0
    # "kill": the with-block tore the session down.  Restore into a fresh
    # session over the same composition: state + parked backlog replay.
    assert read_floe_meta(path)["flow"] == "ck"
    with Session.restore(path, build()) as s2:
        assert s2.quiesce(20)
        assert s2.coordinator.flakes["sum"].state == 25   # 15 + 7 + 3
        assert sorted(m.payload for m in s2.drain() if m.is_data()) == \
            [22, 25]


def test_checkpoint_preserves_half_gathered_window(tmp_path):
    def build():
        flow = Flow("wck")
        src = flow.pellet("src", lambda: FnPellet(lambda x: x))
        win = flow.pellet("win", lambda: SumWindow(4))
        src >> win
        return flow

    path = str(tmp_path / "w.ckpt")
    with build().session() as s:
        s.inject_many("src", [1, 2, 3])
        deadline = time.time() + 10
        while len(s.coordinator.flakes["win"]._window_buf) < 3 and \
                time.time() < deadline:
            time.sleep(0.01)
        s.checkpoint(path)
    with Session.restore(path, build()) as s2:
        s2.inject("src", 4)                  # completes the window
        assert s2.results(timeout=20) == [10]


def test_checkpoint_after_recomposition_restores_on_derived_flow(tmp_path):
    """A recomposition gone wrong can be rolled back: checkpoint before,
    mutate, restore the pre-change state on the matching blueprint."""
    flow = _linear_flow()
    path = str(tmp_path / "pre.ckpt")
    with flow.session() as s:
        s.inject("src", 1)
        assert s.quiesce(10)
        s.drain()
        s.coordinator.flakes["work"].pause()
        s.inject("src", 41)
        s.checkpoint(path)
        # the "bad" change: retire 'work' entirely (backlog dropped!)
        with s.recompose() as tx:
            tx.remove("work", backlog="drop")
        assert "work" not in s.coordinator.flakes
    # roll back to the checkpoint on the original blueprint
    with Session.restore(path, _linear_flow()) as s2:
        assert s2.results(timeout=20) == [41]


# ---------------------------------------------------------------------------
# split rebuild on fan-out-changing rewires (PR-3 satellite fix)
# ---------------------------------------------------------------------------

def test_split_rebuilt_when_fanout_changes():
    flow = Flow("fan")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x))
    a = flow.pellet("a", lambda: Tag("a"))
    b = flow.pellet("b", lambda: Tag("b"))
    src >> a
    src >> b
    with flow.session() as s:
        flake = s.coordinator.flakes["src"]
        split_before = flake.routes["out"][0]
        with s.recompose() as tx:
            tx.unwire("src", "b")
        assert flake.routes["out"][0] is not split_before
        assert len(flake.routes["out"][1]) == 1
        s.inject_many("src", [1, 2, 3])
        assert sorted(s.results()) == [("a", 1), ("a", 2), ("a", 3)]


def test_split_reused_when_group_unchanged():
    """Stateful split policies (round-robin counters) must survive
    rewires that do not touch their fan-out group."""
    flow = Flow("fan2")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x))
    a = flow.pellet("a", lambda: Tag("a"))
    b = flow.pellet("b", lambda: Tag("b"))
    other = flow.pellet("other", lambda: FnPellet(lambda x: x))
    src >> a
    src >> b
    with flow.session() as s:
        flake = s.coordinator.flakes["src"]
        split_before = flake.routes["out"][0]
        with s.recompose() as tx:       # unrelated rewire
            tx.add("tail", lambda: Tag("tail"))
            tx.connect("other", "tail")
        assert flake.routes["out"][0] is split_before


# ---------------------------------------------------------------------------
# cluster sessions
# ---------------------------------------------------------------------------

def test_cluster_add_remove_places_and_releases():
    flow = Flow("cl")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x))
    work = flow.pellet("work", lambda: FnPellet(lambda x: x), cores=2)
    src >> work
    cluster = ClusterManager(ClusterSpec(hosts=2, cores_per_host=8,
                                         placement="spread"))
    with flow.session(cluster=cluster) as s:
        scratch = Flow("scratch")
        handle = scratch.pellet("tag", lambda: Tag("t"), cores=3)
        handle.place(host="h1")
        with s.recompose() as tx:
            tx.add(handle)
            tx.connect("work", "tag")
        assert cluster._placement["tag"] == "h1"
        assert cluster.hosts["h1"].container.allocated.get("tag") == 3
        s.inject("src", 1)
        assert s.results(timeout=30) == [("t", 1)]
        with s.recompose() as tx:
            tx.remove("tag")
        assert "tag" not in cluster._placement
        assert cluster.hosts["h1"].container.allocated.get("tag", 0) == 0
        events = [e["event"] for e in cluster.events]
        assert "unplace" in events
        s.inject("src", 2)
        assert s.results(timeout=30) == [2]


def test_cluster_add_placement_failure_rolls_back():
    flow = Flow("cl2")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x))
    work = flow.pellet("work", lambda: FnPellet(lambda x: x))
    src >> work
    cluster = ClusterManager(ClusterSpec(hosts=1, cores_per_host=8))
    with flow.session(cluster=cluster) as s:
        v0 = s.coordinator.topology_version
        scratch = Flow("scratch")
        bad = scratch.pellet("tag", lambda: Tag("t")).place(host="h9")
        with pytest.raises(Exception, match="unknown host"):
            with s.recompose() as tx:
                tx.add(bad)
                tx.connect("work", "tag")
        assert "tag" not in s.coordinator.flakes
        assert "tag" not in cluster._placement
        assert s.coordinator.topology_version == v0
        s.inject("src", 1)
        assert s.results(timeout=30) == [1]


# ---------------------------------------------------------------------------
# Flow.derive / remove / disconnect (builder support)
# ---------------------------------------------------------------------------

def test_derive_is_independent_copy():
    flow = _linear_flow()
    d = flow.derive()
    d.pellet("extra", lambda: Tag("x"))
    d.stages["work"] >> d.stages["extra"]
    d.remove("extra")
    assert "extra" not in flow.stages
    assert len(flow.edges) == 1
    assert d.stages["work"].factory is flow.stages["work"].factory
    d.stages["work"].batch(8)
    assert "batch_max" not in flow.stages["work"].annotations


def test_flow_disconnect_and_split_claim_release():
    from repro import CompositionError
    flow = Flow("d")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x))
    b = flow.pellet("b", lambda: FnPellet(lambda x: x))
    a.split("hash") >> b
    flow.disconnect("a", "b")
    assert flow.edges == []
    # the group's split claim is released: a different policy is legal now
    a.split("round_robin") >> b
    with pytest.raises(CompositionError, match="no edge"):
        flow.disconnect("a", "b", src_port="nope")
