"""Adaptation strategies (paper §III) + Fig. 4 simulation reproduction."""
import math
import time

import numpy as np
import pytest

from repro.adaptation import (ALPHA, AdaptationController, DynamicAdaptation,
                              HybridAdaptation, Observation, PelletHints,
                              StaticLookahead, TailLatencySLO, divisor_floor,
                              static_allocation)
from repro.adaptation.simulator import (DURATION, EPSILON, PERIOD,
                                        run_i1_experiment)


# ---------------------------------------------------------------------------
# unit: the closed-form static look-ahead (§III)
# ---------------------------------------------------------------------------

def test_static_formula_paper_example():
    """l=1.0s, m=3000 msgs over t=60s, eps=20 -> P=37.5 -> C=10 cores."""
    s = StaticLookahead(latency=1.0, expected_window_messages=3000,
                        window_duration=60.0, epsilon=20.0)
    assert s.cores == 10
    assert s.decide(Observation(0, 0, 0.0, 1.0, 0)) == 10  # never adapts


def test_static_allocation_cascades_selectivity():
    hints = [PelletHints(latency=1.0, selectivity=2.0),
             PelletHints(latency=0.5, selectivity=1.0),
             PelletHints(latency=1.0, selectivity=1.0)]
    cores = static_allocation(hints, m1=800, window_duration=60, epsilon=20)
    # m = [800, 1600, 1600]; P = [10, 10, 20]; C = [3, 3, 5]
    assert cores == [3, 3, 5]


# ---------------------------------------------------------------------------
# unit: Algorithm 1 dynamics
# ---------------------------------------------------------------------------

def obs(rate, queue=0, cores=1, latency=1.0, t=0.0):
    return Observation(t=t, queue_length=queue, input_rate=rate,
                       service_latency=latency, cores=cores)


def test_dynamic_scales_up_under_load():
    d = DynamicAdaptation()
    assert d.decide(obs(rate=50.0, cores=1)) > 1


def test_dynamic_holds_at_capacity():
    d = DynamicAdaptation()
    # 2 cores * 4 inst / 1s = 8 msgs/s capacity; rate 7.5 inside the band
    assert d.decide(obs(rate=7.5, cores=2)) == 2


def test_dynamic_hysteresis_no_flap():
    """Scale-down only if the reduced allocation still sustains demand."""
    d = DynamicAdaptation(threshold=0.1)
    # 3 cores = 12/s; demand 7.5; 2 cores = 8/s; 7.5 > 8*0.9 -> hold
    assert d.decide(obs(rate=7.5, cores=3)) == 3
    # demand 5.0 < 8*0.9 -> release one core
    assert d.decide(obs(rate=5.0, cores=3)) == 2


def test_dynamic_quiesces_to_zero():
    d = DynamicAdaptation()
    assert d.decide(obs(rate=0.0, queue=0, cores=3)) == 0


def test_dynamic_drains_backlog():
    d = DynamicAdaptation(drain_horizon=30.0)
    # idle input but 300 queued -> demand 10/s -> needs >0 cores
    assert d.decide(obs(rate=0.0, queue=300, cores=0)) >= 1


def test_dynamic_respects_max_cores():
    d = DynamicAdaptation(max_cores=8)
    c = 1
    for _ in range(20):
        c = d.decide(obs(rate=1e6, cores=c))
    assert c == 8


# ---------------------------------------------------------------------------
# unit: tail-latency SLO strategy (queue-wait p95, PR 6 percentiles)
# ---------------------------------------------------------------------------

def slo_obs(wait, rate=1.0, queue=0, cores=1, latency=0.01):
    return Observation(t=0.0, queue_length=queue, input_rate=rate,
                       service_latency=latency, cores=cores,
                       queue_wait_p95=wait)


def test_slo_scales_out_on_breach_with_live_traffic():
    s = TailLatencySLO(queue_slo=0.01, max_cores=8)
    assert s.decide(slo_obs(wait=0.1, queue=3, cores=1)) > 1
    assert s.decide(slo_obs(wait=0.1, queue=0, rate=5.0, cores=1)) > 1


def test_slo_ignores_stale_breach_when_idle():
    """The histograms are cumulative: a past breach with no queued work
    and no arrivals must not keep scaling out."""
    s = TailLatencySLO(queue_slo=0.01)
    assert s.decide(slo_obs(wait=0.1, queue=0, rate=0.0, cores=3)) == 0


def test_slo_holds_inside_budget():
    s = TailLatencySLO(queue_slo=0.05)
    # capacity at 0 fewer cores comfortably covers demand -> release one;
    # at the floor, hold
    assert s.decide(slo_obs(wait=0.01, rate=50.0, cores=1,
                            latency=0.01)) == 1


def test_slo_releases_with_hysteresis():
    s = TailLatencySLO(queue_slo=0.05, threshold=0.1)
    # 1 core * ALPHA / 0.01s = 400/s; demand 10/s << 360 -> shed to 1
    assert s.decide(slo_obs(wait=0.01, rate=10.0, cores=2,
                            latency=0.01)) == 1
    # demand right at the reduced capacity -> hold (no flap)
    assert s.decide(slo_obs(wait=0.01, rate=395.0, cores=2,
                            latency=0.01)) == 2


def test_slo_respects_max_cores_and_quiesces():
    s = TailLatencySLO(queue_slo=0.001, max_cores=4)
    c = 1
    for _ in range(10):
        c = s.decide(slo_obs(wait=1.0, queue=5, cores=c))
    assert c == 4
    assert s.decide(slo_obs(wait=1.0, queue=0, rate=0.0, cores=c)) == 0


def test_slo_prefers_windowed_signal():
    """When the producer carries the windowed p95, the strategy keys on
    it: a stale cumulative breach with a recovered window scales IN, a
    fresh windowed breach scales OUT, and a window-less legacy producer
    falls back to the cumulative signal."""
    import dataclasses
    s = TailLatencySLO(queue_slo=0.01)
    stale = dataclasses.replace(slo_obs(wait=0.5, queue=3, cores=2),
                                queue_wait_p95_window=0.001)
    assert s.decide(stale) <= 2              # no scale-out on old history
    fresh = dataclasses.replace(slo_obs(wait=0.001, queue=3, cores=1),
                                queue_wait_p95_window=0.5)
    assert s.decide(fresh) > 1               # windowed breach drives out
    assert s.decide(slo_obs(wait=0.5, queue=3, cores=1)) > 1   # legacy
    # the rebase sentinel must never read as a breach (or crash)
    sentinel = dataclasses.replace(slo_obs(wait=0.0, queue=3, cores=2),
                                   queue_wait_p95_window=-1.0)
    assert s.decide(sentinel) <= 2


def test_slo_policy_compiles():
    from repro.api.policies import ElasticPolicy
    strat = ElasticPolicy(strategy="slo", queue_slo=0.02,
                          max_cores=6).build_strategy()
    assert isinstance(strat, TailLatencySLO)
    assert strat.queue_slo == 0.02 and strat.max_cores == 6
    with pytest.raises(Exception):
        ElasticPolicy(strategy="slo", queue_slo=0.0)


# ---------------------------------------------------------------------------
# unit: hybrid switching (§III, built here — paper future work)
# ---------------------------------------------------------------------------

def make_hybrid(hint=50.0):
    return HybridAdaptation(
        StaticLookahead(1.0, hint * 60, 60, 20),
        DynamicAdaptation(),
        hinted_rate=lambda t: hint,
        veer_threshold=0.5, latency_slo=20.0)


def test_hybrid_stays_static_near_hint():
    h = make_hybrid()
    c = h.decide(obs(rate=50.0, cores=10))
    assert h.mode == "static" and c == h.static.cores


def test_hybrid_switches_on_veer_and_back():
    h = make_hybrid()
    h.decide(obs(rate=50.0, cores=10, t=0.0))
    assert h.mode == "static"
    h.decide(obs(rate=200.0, cores=10, t=5.0))     # veered >50%
    assert h.mode == "dynamic"
    h.decide(obs(rate=52.0, queue=0, cores=12, t=10.0))  # stabilized
    assert h.mode == "static"
    assert [m for _, m in h.switches] == ["dynamic", "static"]


def test_hybrid_switches_on_backlog():
    """Even without a rate veer, a building backlog (predicted latency
    violation) flips hybrid to dynamic."""
    h = make_hybrid()
    h.decide(obs(rate=50.0, queue=10000, cores=10, t=0.0))
    assert h.mode == "dynamic"


def test_hybrid_quiesces_idle():
    h = make_hybrid()
    assert h.decide(obs(rate=0.0, queue=0, cores=10)) == 0


# ---------------------------------------------------------------------------
# Fig. 4 reproduction (simulation, as in the paper §IV.C)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fig4():
    return {k: run_i1_experiment(k, horizon=3600.0)
            for k in ("periodic", "spiky", "random")}


def test_fig4_periodic_static_drains_at_75s(fig4):
    """Paper: static meets the 80s threshold, draining at ~75s."""
    drains = fig4["periodic"]["static"].drain_times("I1", PERIOD, DURATION)
    assert all(70.0 <= d <= 80.0 for d in drains)
    assert fig4["periodic"]["static"].violations("I1", PERIOD, DURATION,
                                                 EPSILON) == 0


def test_fig4_periodic_dynamic_finishes_earlier_with_more_peak(fig4):
    """Paper: dynamic finishes earlier (~70s vs 75s) at the cost of extra
    resources in that duration (larger peak allocation)."""
    st, dy = fig4["periodic"]["static"], fig4["periodic"]["dynamic"]
    st_d = st.drain_times("I1", PERIOD, DURATION)
    dy_d = dy.drain_times("I1", PERIOD, DURATION)
    assert np.mean(dy_d) < np.mean(st_d)
    assert max(dy.cores["I1"]) > max(st.cores["I1"])


def test_fig4_periodic_hybrid_like_static_but_quiesces(fig4):
    hy = fig4["periodic"]["hybrid"]
    assert hy.violations("I1", PERIOD, DURATION, EPSILON) == 0
    assert min(hy.cores["I1"]) == 0          # quiesces to 0 between windows
    # cheaper than the always-on static allocation overall
    assert hy.core_seconds("I1") < fig4["periodic"]["static"].core_seconds("I1")


def test_fig4_spiky_static_misses_dynamic_meets(fig4):
    """Paper: static misses the latency tolerance on data surges; dynamic
    processes all messages within tolerance; hybrid does too with fewer
    resources than dynamic."""
    st = fig4["spiky"]["static"]
    dy = fig4["spiky"]["dynamic"]
    hy = fig4["spiky"]["hybrid"]
    assert st.violations("I1", PERIOD, DURATION, EPSILON) > 0
    assert dy.violations("I1", PERIOD, DURATION, EPSILON) == 0
    assert hy.violations("I1", PERIOD, DURATION, EPSILON) == 0
    assert hy.core_seconds("I1") < dy.core_seconds("I1")
    assert max(dy.cores["I1"]) > max(st.cores["I1"])


def test_fig4_random_static_queue_accumulates(fig4):
    """Paper: static's queue (hence queueing latency) accumulates over time;
    dynamic and hybrid keep pending messages negligible."""
    st = fig4["random"]["static"]
    dy = fig4["random"]["dynamic"]
    hy = fig4["random"]["hybrid"]
    assert st.final_queue("I1") > 5000            # unbounded growth
    assert dy.max_queue("I1") < 1000              # negligible backlog
    assert hy.max_queue("I1") < 2000
    assert hy.final_queue("I1") < 2000


def test_fig4_random_resource_ratio_near_paper(fig4):
    """Paper: cumulative resources static:dynamic:hybrid = 0.87:1.00:0.98."""
    s = fig4["random"]["static"].core_seconds("I1")
    d = fig4["random"]["dynamic"].core_seconds("I1")
    h = fig4["random"]["hybrid"].core_seconds("I1")
    assert 0.75 <= s / d <= 0.95, f"static:dynamic = {s/d:.2f}, paper 0.87"
    assert 0.90 <= h / d <= 1.0, f"hybrid:dynamic = {h/d:.2f}, paper 0.98"


# ---------------------------------------------------------------------------
# live controller against a real running graph
# ---------------------------------------------------------------------------

def test_live_controller_scales_real_flake():
    from repro.core import Coordinator, FloeGraph, FnPellet

    def work(x):
        time.sleep(0.02)
        return x

    g = FloeGraph("live")
    g.add("p", lambda: FnPellet(work), cores=1)
    coord = Coordinator(g).start()
    ctrl = AdaptationController(
        coord, {"p": DynamicAdaptation(max_cores=8, drain_horizon=1.0)},
        sample_interval=0.1).start()
    try:
        t_end = time.time() + 1.2
        while time.time() < t_end:      # offered load >> 1-core capacity
            coord.inject("p", 1)
            time.sleep(0.002)
        assert coord.flakes["p"].cores > 1     # controller scaled up
        assert coord.run_until_quiescent(timeout=60)
        # after the backlog drains and input stops, it scales back down
        for _ in range(30):
            ctrl.step_once()
        assert coord.flakes["p"].cores == 0    # quiesced
        processed = coord.flakes["p"].stats.processed
        assert processed == coord.flakes["p"].stats.arrived
    finally:
        ctrl.stop()
        coord.stop()


# ---------------------------------------------------------------------------
# elastic mesh planning (SPMD layer)
# ---------------------------------------------------------------------------

def test_divisor_floor():
    assert divisor_floor(16, 5) == 4
    assert divisor_floor(16, 16) == 16
    assert divisor_floor(16, 1) == 1
    assert divisor_floor(12, 7) == 6


def test_elastic_mesh_manager_plans():
    from repro.adaptation import ElasticMeshManager
    m = ElasticMeshManager(devices=list(range(16)), model_parallel=4)
    assert m.max_replicas == 4
    plan = m.plan(3)   # 3 not a divisor of 4 -> rounds down to 2
    assert plan.shape == (2, 4) and plan.n_devices == 8
    assert m.plan(100).shape == (4, 4)


def test_elastic_scaler_logs_decisions():
    from repro.adaptation import ElasticMeshManager, ElasticServingScaler
    m = ElasticMeshManager(devices=list(range(8)), model_parallel=1)
    sc = ElasticServingScaler(m, DynamicAdaptation(max_cores=8))
    assert sc.current_replicas == 8
    changed = sc.observe(obs(rate=0.5, cores=8, latency=1.0))
    assert changed and sc.current_replicas < 8
    assert sc.log[-1].reason == "resize"
