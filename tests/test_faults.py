"""Fault-tolerance plane: failure detection, automatic recovery,
row retry / dead-letter ladder, transport hardening, seeded chaos.

The acceptance scenario (``test_chaos_acceptance``) is the headline: a
3-host cluster loses one VM mid-load while the cross-host wire drops 5%
of sends and one pellet crash-loops on poison rows — the session must
recover automatically with ZERO lost rows (duplicates allowed and
counted), the poison rows in the dead-letter queue, and the stage
quarantined.
"""
import os
import time

import pytest

from repro import (ChaosController, ClusterSpec, FaultPlan, FnPellet,
                   Flow, PelletCrashError, RecoveryPolicy, census)
from repro.faults import CheckpointPolicy, CrashRule, FaultyWire
from repro.cluster.transport import (SerializingTransport,
                                     TransientTransportError, TransportError)


def _wait(pred, timeout=10.0, step=0.02):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if pred():
            return True
        time.sleep(step)
    return pred()


# -- policies & vocabulary ----------------------------------------------------

def test_policy_validation():
    with pytest.raises(ValueError):
        CheckpointPolicy(interval_s=0)
    with pytest.raises(ValueError):
        RecoveryPolicy(heartbeat_interval_s=0)
    with pytest.raises(ValueError):
        FaultPlan().crash_pellet("x")            # needs on_nth or match
    with pytest.raises(ValueError):
        FaultyWire(drop_rate=1.5)


def test_census_accounting():
    c = census([1, 2, 3, 4], [1, 2, 2, 3], dead=[4])
    assert c["lost_count"] == 0 and c["duplicates"] == 1
    assert c["dead_lettered"] == 1
    c = census([1, 2, 3], [1])
    assert c["lost"] == [2, 3]


def test_faulty_wire_is_deterministic_per_seed():
    def run(seed):
        w = FaultyWire(drop_rate=0.3, dup_rate=0.2, reorder_rate=0.5,
                       seed=seed)
        events = []
        for i in range(200):
            try:
                w.before_send([i, i + 1])
                events.append(("ok", w.should_duplicate()))
            except TransientTransportError:
                events.append(("drop", None))
        return events, w.describe()
    assert run(7) == run(7)
    assert run(7) != run(8)


# -- transport hardening ------------------------------------------------------

def _two_host_session(flow, **kw):
    return flow.session(
        cluster=ClusterSpec(hosts=2, cores_per_host=8,
                            transport="serializing"), **kw)


def test_transport_retries_dropped_sends_zero_loss():
    flow = Flow("wire")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x)).place(host="h0")
    b = flow.pellet("b", lambda: FnPellet(lambda x: x * 3)).place(host="h1")
    a >> b
    with _two_host_session(flow) as s:
        chaos = ChaosController(
            s.coordinator,
            FaultPlan(seed=11).flaky_wire(drop_rate=0.2, max_retries=10)
        ).start()
        s.inject_many(a, list(range(300)))
        out = s.results(timeout=60)
        c = census([i * 3 for i in range(300)], out)
        assert c["lost_count"] == 0
        # every chaos drop surfaced as a transport retry, never a loss
        assert chaos.wire.drops > 0
        assert s.cluster.transport.stats.retries == chaos.wire.drops
        chaos.stop()


def test_transport_duplicates_are_counted_not_lost():
    flow = Flow("dup")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x)).place(host="h0")
    b = flow.pellet("b", lambda: FnPellet(lambda x: x)).place(host="h1")
    a >> b
    with _two_host_session(flow) as s:
        chaos = ChaosController(
            s.coordinator,
            FaultPlan(seed=5).flaky_wire(dup_rate=1.0)).start()
        s.inject_many(a, list(range(20)))
        out = s.results(timeout=60)
        c = census(list(range(20)), out)
        assert c["lost_count"] == 0
        assert c["duplicates"] > 0
        assert s.cluster.transport.stats.duplicated == c["duplicates"]
        chaos.stop()


def test_transport_retry_exhaustion_is_permanent_error():
    t = SerializingTransport(max_retries=2, retry_backoff_s=0.0)

    class _AlwaysDrop:
        def before_send(self, msgs):
            raise TransientTransportError("chaos: always drop")

        def should_duplicate(self):
            return False

    t.fault_injector = _AlwaysDrop()
    flow = Flow("exh")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x))
    with flow.session() as s:
        from repro.core.message import Message
        with pytest.raises(TransportError):
            t.deliver(s.coordinator.flakes["a"], "in",
                      [Message(payload=1)])
    assert t.stats.retries == 2


def test_wire_trace_spans_visible_in_session_trace():
    flow = Flow("spans")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x)).place(host="h0")
    b = flow.pellet("b", lambda: FnPellet(lambda x: x + 1)).place(host="h1")
    a >> b
    with _two_host_session(flow, trace_sample=1.0) as s:
        s.inject(a, 1)
        assert s.results(timeout=30) == [2]
        tids = s.trace()
        assert tids
        stages = [sp["stage"] for sp in s.trace(tids[0])]
        assert any(st.startswith("wire:") for st in stages), stages


# -- row retry / dead letters -------------------------------------------------

def test_transient_row_error_retried_then_delivered():
    calls = {}

    def mk():
        def f(x):
            calls[x] = calls.get(x, 0) + 1
            if x == 7 and calls[x] == 1:
                raise ValueError("transient")
            return x
        return FnPellet(f)

    flow = Flow("retry")
    a = flow.pellet("a", mk)
    with flow.session(recovery=RecoveryPolicy(checkpoint=None,
                                              max_row_retries=2)) as s:
        s.inject_many(a, list(range(20)))
        out = s.results(timeout=30)
        assert sorted(out) == list(range(20))       # 7 recovered on retry
        assert calls[7] == 2
        assert s.dead_letters() == []


def test_poison_row_lands_in_dead_letter_queue():
    def mk():
        def f(x):
            if x == 13:
                raise ValueError("poison")
            return x + 1
        return FnPellet(f)

    flow = Flow("dlq")
    a = flow.pellet("a", mk)
    with flow.session(recovery=RecoveryPolicy(checkpoint=None,
                                              max_row_retries=2)) as s:
        s.inject_many(a, list(range(30)))
        out = s.results(timeout=30)
        assert sorted(out) == [i + 1 for i in range(30) if i != 13]
        (letter,) = s.dead_letters()
        assert letter.payload == 13 and letter.stage == "a"
        assert letter.attempts == 3                 # 1 try + 2 retries
        assert "poison" in letter.error
        # drain clears
        assert len(s.dead_letters(drain=True)) == 1
        assert s.dead_letters() == []
        assert s.faults.dead_letters.total == 1


def test_dead_letter_without_plane_raises():
    from repro import SessionStateError
    flow = Flow("noplane")
    flow.pellet("a", lambda: FnPellet(lambda x: x))
    with flow.session() as s:
        with pytest.raises(SessionStateError):
            s.dead_letters()


# -- pellet crash restarts / quarantine ---------------------------------------

def test_pellet_crash_restarts_with_fresh_instance():
    flow = Flow("restart")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x))
    pol = RecoveryPolicy(checkpoint=None, max_restarts=3,
                         restart_backoff_s=0.01, max_row_retries=1)
    with flow.session(recovery=pol) as s:
        flake = s.coordinator.flakes["a"]
        v0 = flake.version
        chaos = ChaosController(
            s.coordinator,
            FaultPlan(seed=2).crash_pellet("a", on_nth=3)).start()
        s.inject_many(a, list(range(10)))
        out = s.results(timeout=30)
        assert _wait(lambda: flake.version > v0, timeout=10)
        d = s.faults.describe()
        assert d["restarts"].get("a") == 1
        assert d["quarantined"] == []
        # the crashed row itself was retried and delivered: nothing lost
        assert census(list(range(10)), out)["lost_count"] == 0
        chaos.stop()


def test_crash_loop_quarantines_healthy_rows_flow():
    flow = Flow("quar")
    b = flow.pellet("b", lambda: FnPellet(lambda x: x))
    pol = RecoveryPolicy(checkpoint=None, max_restarts=2,
                         restart_backoff_s=0.01, max_row_retries=1)
    with flow.session(recovery=pol) as s:
        chaos = ChaosController(
            s.coordinator,
            FaultPlan(seed=1).crash_pellet("b", match=lambda p: p % 10 == 3)
        ).start()
        s.inject_many(b, list(range(40)))
        out = s.results(timeout=60)
        d = s.faults.describe()
        # circuit broken: stage quarantined, but every healthy row delivered
        assert d["quarantined"] == ["b"]
        assert sorted(out) == [i for i in range(40) if i % 10 != 3]
        assert {l.payload for l in s.dead_letters()} == {3, 13, 23, 33}
        assert any(e["kind"] == "flake_quarantined" for e in s.events())
        chaos.stop()


def test_dead_dispatch_thread_is_revived():
    flow = Flow("revive")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x))
    pol = RecoveryPolicy(checkpoint=None, heartbeat_interval_s=0.05,
                         suspicion_timeout_s=0.2)
    with flow.session(recovery=pol) as s:
        flake = s.coordinator.flakes["a"]
        # simulate the dispatch thread dying of a bug: swap in a corpse
        import threading
        dead = threading.Thread(target=lambda: None)
        dead.start()
        dead.join()
        flake._thread = dead
        assert _wait(lambda: flake._thread.is_alive(), timeout=10)
        s.inject(a, 99)
        assert s.results(timeout=30) == [99]
        assert any(e["kind"] == "flake_failed"
                   and e.get("stage") == "a" for e in s.events())


# -- auto-checkpointing -------------------------------------------------------

def test_background_checkpoints_rotate_and_truncate_journal(tmp_path):
    flow = Flow("auto")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x))
    pol = RecoveryPolicy(
        checkpoint=CheckpointPolicy(interval_s=0.15, dir=str(tmp_path),
                                    keep=2))
    with flow.session(recovery=pol) as s:
        s.inject_many(a, list(range(10)))
        s.results()
        assert len(s.faults._journal) == 10
        assert _wait(lambda: s.faults._ckpt_epoch >= 3, timeout=15)
        # journal truncated by the cut (rows are inside the checkpoint now)
        assert len(s.faults._journal) == 0
        cuts = [n for n in os.listdir(tmp_path) if n.endswith(".floe")]
        assert len(cuts) <= 2                       # retention
        assert s.faults.checkpoint_path in [
            os.path.join(str(tmp_path), n) for n in cuts]
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_crash_inside_frozen_releases_freeze():
    """A raising body inside ``frozen()`` must unfreeze the graph: the
    session keeps dispatching and injecting afterwards."""
    flow = Flow("frz")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x))
    with flow.session() as s:
        coord = s.coordinator
        with pytest.raises(RuntimeError, match="boom"):
            with coord.frozen(timeout=10):
                raise RuntimeError("boom")
        s.inject(a, 5)
        assert s.results(timeout=30) == [5]


# -- idempotent shutdown (satellite) ------------------------------------------

def test_coordinator_stop_is_idempotent_and_audit_clean():
    flow = Flow("stop")
    flow.pellet("a", lambda: FnPellet(lambda x: x))
    s = flow.session(recovery=RecoveryPolicy(
        checkpoint=CheckpointPolicy(interval_s=0.1))).open()
    coord = s.coordinator
    tele = coord.telemetry
    s.inject("a", 1)
    s.results()
    s.close()
    n_events = len(tele.events.records())
    coord.stop()                                   # second stop: no-op
    coord.stop()
    assert coord.core_audit() == {}
    assert len(tele.events.records()) == n_events  # no re-fired events
    s.close()                                      # session close also safe
    # the fault plane's private checkpoint dir is gone
    assert coord._faults._ckpt_dir is None


def test_cluster_stop_idempotent_releases_once():
    flow = Flow("cstop")
    flow.pellet("a", lambda: FnPellet(lambda x: x))
    mgr_holder = {}
    with flow.session(cluster=ClusterSpec(hosts=2)) as s:
        mgr_holder["m"] = s.cluster
        s.inject("a", 1)
        s.results()
        coord = s.coordinator
    coord.stop()
    coord.stop()
    assert coord.core_audit() == {}


# -- host failure recovery ----------------------------------------------------

def _three_host_flow():
    flow = Flow("rec")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x)).place(host="h0")
    mid = flow.pellet("mid",
                      lambda: FnPellet(lambda x: x + 1000)).place(host="h1")
    snk = flow.pellet("snk", lambda: FnPellet(lambda x: x)).place(host="h2")
    src >> mid
    mid >> snk
    return flow, src


def _recovery_policy():
    return RecoveryPolicy(
        checkpoint=CheckpointPolicy(interval_s=0.25, freeze_timeout_s=10.0),
        heartbeat_interval_s=0.05, suspicion_timeout_s=0.15,
        max_row_retries=1, restart_backoff_s=0.01)


def test_host_failure_recovers_zero_loss():
    flow, src = _three_host_flow()
    spec = ClusterSpec(hosts=3, cores_per_host=8, transport="serializing")
    with flow.session(cluster=spec, recovery=_recovery_policy()) as s:
        chaos = ChaosController(
            s.coordinator, FaultPlan(seed=3).kill_host("h1", at_s=0.4)
        ).start()
        injected = []
        for i in range(1500):
            s.inject(src, i)
            injected.append(i + 1000)
            time.sleep(0.0005)
        assert _wait(lambda: s.faults.recoveries, timeout=20), \
            "host failure was never recovered"
        out = s.results(timeout=60)
        c = census(injected, out)
        assert c["lost_count"] == 0, c["lost"][:10]
        rec = s.faults.last_recovery
        assert rec["host"] == "h1" and rec["flakes"] == ["mid"]
        assert rec["placed"]["mid"] != "h1"       # respawned elsewhere
        assert any(e["kind"] == "host_failed" for e in s.events())
        assert any(e["kind"] == "recovery" for e in s.events())
        # the dead VM's cores are fully released
        assert s.cluster.hosts["h1"].container.allocated == {}
        chaos.stop()
    assert s._coord is None


def test_chaos_acceptance():
    """The ISSUE acceptance scenario: kill 1 of 3 hosts mid-load, 5%
    transport drop, one crash-looping pellet — automatic recovery, zero
    lost rows (dups counted), poison rows dead-lettered, stage
    quarantined."""
    flow = Flow("accept")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x)).place(host="h0")
    mid = flow.pellet("mid",
                      lambda: FnPellet(lambda x: x + 1000)).place(host="h1")
    snk = flow.pellet("snk", lambda: FnPellet(lambda x: x)).place(host="h2")
    src >> mid
    mid >> snk
    pol = RecoveryPolicy(
        checkpoint=CheckpointPolicy(interval_s=0.25, freeze_timeout_s=10.0),
        heartbeat_interval_s=0.05, suspicion_timeout_s=0.15,
        max_restarts=2, restart_backoff_s=0.01, max_row_retries=1)
    spec = ClusterSpec(hosts=3, cores_per_host=8, transport="serializing")
    n = 1200
    poison = {p for p in range(n) if p % 97 == 13}
    with flow.session(cluster=spec, recovery=pol) as s:
        plan = (FaultPlan(seed=7)
                .kill_host("h2", at_s=0.4)
                .crash_pellet("src", match=lambda p: p % 97 == 13)
                .flaky_wire(drop_rate=0.05, delay_s=0.0005, max_retries=8))
        chaos = ChaosController(s.coordinator, plan).start()
        for i in range(n):
            s.inject(src, i)
            time.sleep(0.0004)
        assert _wait(lambda: s.faults.recoveries, timeout=25), \
            "host failure was never recovered"
        out = s.results(timeout=90)
        dead = {l.payload for l in s.dead_letters()}
        expect = [i + 1000 for i in range(n) if i not in poison]
        c = census(expect, out, dead=set())
        # headline guarantee: nothing lost; duplicates allowed & counted
        assert c["lost_count"] == 0, c["lost"][:10]
        d = s.faults.describe()
        assert d["quarantined"] == ["src"]          # crash-loop broke
        assert dead and dead <= poison              # poison rows in DLQ
        assert s.faults.last_recovery["host"] == "h2"
        assert chaos.wire.drops > 0                 # the wire really dropped
        report = chaos.describe()
        assert report["kills"] and report["crashes"]["src"] > 0
        chaos.stop()