"""Fixture: lock-order cycle (FL001), self-deadlock (FL002),
cross-instance nesting (FL003) and ambiguous lock (FL004).

Intentionally broken — input for tests/test_analysis.py, never imported.
"""
import threading


class Ledger:
    def __init__(self):
        self._book_lock = threading.Lock()
        self._audit_lock = threading.Lock()
        self._plain = threading.Lock()

    def post(self):
        with self._book_lock:
            with self._audit_lock:      # order: book -> audit
                pass

    def audit(self):
        with self._audit_lock:
            with self._book_lock:       # order: audit -> book  (cycle!)
                pass

    def reenter(self):
        with self._plain:
            with self._plain:           # FL002: non-reentrant self-deadlock
                pass

    def merge(self, other):
        with self._book_lock:
            with other._book_lock:      # FL003: distinct instances, same class
                pass


class Shelf:
    def __init__(self):
        self._lock2 = threading.Lock()


class Crate:
    def __init__(self):
        self._lock2 = threading.Lock()

    def pack(self, thing):
        with thing._lock2:              # FL004: Shelf or Crate? ambiguous
            pass
