"""Fixture: pellet-contract violations (FL301–FL305).

Intentionally broken — analyzer input only (framework classes are
resolved by base-class NAME, so this file needs no real imports).
"""
import threading


class PushPellet:          # stand-in so the fixture is self-contained
    pass


class ArrayOnly(PushPellet):
    """FL301: array path with no row-wise fallback."""

    def compute_array(self, array):
        return array * 2


class DeadFlag(PushPellet):
    """FL302: vectorized=True that nothing honors."""

    vectorized = True

    def compute(self, payload):
        return payload


class BadStateShape(PushPellet):
    """FL303: __floe_state__ is not a literal name tuple."""

    __floe_state__ = ("a", 3)

    def compute(self, payload):
        return payload


class LockInState(PushPellet):
    """FL304: checkpoint state includes an unpicklable lock."""

    __floe_state__ = ("total", "guard")

    def __init__(self):
        self.total = 0
        self.guard = threading.Lock()

    def compute(self, payload):
        return payload


class PhantomState(PushPellet):
    """FL305: __floe_state__ names an attribute never assigned."""

    __floe_state__ = ("missing",)

    def compute(self, payload):
        return payload
