"""Fixture: a module every analyzer should pass clean.

Consistent lock order, honored guarded-by annotations, and a pellet that
meets every contract.
"""
import threading


class Account:
    def __init__(self):
        self._outer = threading.Lock()
        self._inner = threading.Lock()
        self._balance = 0       # guarded-by: _inner

    def deposit(self, n):
        with self._outer:
            with self._inner:   # always outer -> inner
                self._balance += n

    def balance(self):
        with self._inner:
            return self._balance


class PushPellet:          # stand-in base, resolved by name
    pass


class Doubler(PushPellet):
    __floe_state__ = ("total",)

    def __init__(self):
        self.total = 0

    def compute(self, payload):
        self.total += payload
        return payload * 2

    def compute_array(self, array):
        return array * 2
