"""Fixture: dataflow hazards for the STATIC flow linter (FL201, FL203,
FL204) — written in the examples idiom (flow built inside main()).

Intentionally hazardous — linted as text, never executed.
"""
from repro.api.builder import Flow
from repro.core.pellet import FnPellet


def main():
    flow = Flow("wedge")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x))
    join = flow.pellet("join", lambda: FnPellet(lambda x: x))
    loop = flow.pellet("loop", lambda: FnPellet(lambda x: x))
    out = flow.sink("out", None, exactly_once=True)
    # FL201: a cycle-only island no source reaches
    isl_a = flow.pellet("isl_a", lambda: FnPellet(lambda x: x))
    isl_b = flow.pellet("isl_b", lambda: FnPellet(lambda x: x))
    isl_a >> isl_b
    isl_b >> isl_a
    # FL203: join's fan-in counts the back-edge from loop
    src >> join
    join >> loop
    loop >> join
    # FL204: exactly-once sink without key= downstream of the cycle
    join >> out
    with flow.session() as s:
        s.run()


if __name__ == "__main__":
    main()
