"""Fixture: guarded-by violations (FL101), unknown locks in annotations
(FL102, FL103).  Intentionally broken — analyzer input only.
"""
import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._n = 0             # guarded-by: _lock
        self._hist = []         # guarded-by: _lock
        self._ghost = 0         # guarded-by: _mystery   (FL102: no such lock)

    def bump(self):
        with self._lock:
            self._n += 1
            self._hist.append(self._n)

    def bump_via_cond(self):
        with self._cond:        # Condition aliases _lock: this is fine
            self._n += 1

    def racy_read(self):
        return self._n          # FL101: no lock held

    def _helper(self):          # requires-lock: _lock
        self._hist.clear()      # fine: declared contract

    def _bad_helper(self):      # requires-lock: _absent   (FL103)
        return len(self._hist)


def poke(c):
    c._n = 99                   # FL101: cross-object write, no lock
