"""Array-payload fast path: ArrayBatch carriers end-to-end.

Covers the tentpole guarantees: a drained batch of stackable payloads
travels between vectorized stages as ONE stacked array (no per-message
unstack), while every engine invariant holds — zero-loss/zero-dup census,
landmark boundaries, per-key FIFO under hash splits, BatchItemError
row-wise degradation, ragged-payload fallback, row-accurate credits/stats,
checkpoint capture, and live migration of in-flight carriers.  Plus the
``Channel.put_many`` shared-deadline regression (satellite bugfix).
"""
import threading
import time

import numpy as np
import pytest

from conftest import wait_until
from repro.api import Flow
from repro.core import (ArrayBatch, Coordinator, FloeGraph, FnPellet,
                        Message, PushPellet, WindowPellet, stable_hash)
from repro.core.engine import Channel


def _vec(X):
    return np.asarray(X) * 2.0


# -- Channel.put_many shared deadline (satellite bugfix) -----------------------

def test_put_many_timeout_is_one_shared_deadline():
    """A multi-chunk admit against a slow consumer must fail within ONE
    timeout wall-clock, not N x timeout (the old per-chunk allowance let a
    trickle-draining consumer stretch a 0.3s timeout to seconds)."""
    ch = Channel(capacity=1)

    def slow_consumer():
        while not stop.is_set():
            ch.pop_up_to(1)
            time.sleep(0.05)

    stop = threading.Event()
    t = threading.Thread(target=slow_consumer, daemon=True)
    t.start()
    try:
        t0 = time.time()
        with pytest.raises(TimeoutError) as exc:
            ch.put_many([Message(payload=i) for i in range(100)],
                        timeout=0.3)
        elapsed = time.time() - t0
        # the consumer keeps freeing one slot per 50ms, so the old code
        # would grind through all 100 chunks (~5s) without ever raising
        assert elapsed < 2.0, f"deadline not shared: {elapsed:.2f}s"
        assert 0 < exc.value.appended < 100   # rollback contract intact
    finally:
        stop.set()
        t.join(timeout=5)


def test_put_many_counts_carrier_rows_against_capacity():
    ch = Channel(capacity=10)
    ab = ArrayBatch(np.zeros((8, 4), np.float32))
    ch.put(Message(payload=ab))
    assert len(ch) == 8                      # rows, not entries
    ch.put_many([Message(payload=i) for i in range(2)])
    with pytest.raises(TimeoutError):        # 8 + 2 rows = full
        ch.put(Message(payload="x"), timeout=0.05)
    got = ch.pop_up_to(1)
    assert isinstance(got[0].payload, ArrayBatch)
    assert len(ch) == 2


# -- census + amortization -----------------------------------------------------

def test_array_chain_one_call_per_hop_census_exact():
    calls = {"a": [], "b": []}

    def stage(tag):
        def fn(X):
            calls[tag].append(np.asarray(X).shape)
            return np.asarray(X) + 1.0
        return fn

    n = 300
    g = FloeGraph("chain")
    g.add("a", lambda: FnPellet(stage("a"), vectorized=True,
                                sequential=True),
          batch_max=64, batch_array=True)
    g.add("b", lambda: FnPellet(stage("b"), vectorized=True,
                                sequential=True),
          batch_max=64, batch_array=True)
    g.connect("a", "b")
    coord = Coordinator(g).start()
    try:
        coord.flakes["a"].pause()
        coord.inject_many("a", [float(i) for i in range(n)])
        coord.flakes["a"].resume()
        assert coord.run_until_quiescent(timeout=60)
        out = sorted(float(m.payload) for m in coord.drain_outputs()
                     if m.is_data())
        assert out == [i + 2.0 for i in range(n)]        # 0 lost / 0 dup
        # stage b consumed stacked arrays directly: one call per carrier,
        # far fewer calls than messages, never a length-1 unstack storm
        assert len(calls["b"]) < n / 4
        assert all(len(s) == 1 and s[0] > 1 for s in calls["b"])
        for name in ("a", "b"):
            st = coord.flakes[name].stats
            assert st.arrived == st.processed == n       # rows, exact
            assert st.emitted == n
        assert not coord.errors, coord.errors[:3]
    finally:
        coord.stop()


def test_array_batches_never_span_a_landmark():
    n = 120
    g = FloeGraph("lm")
    g.add("p", lambda: FnPellet(_vec, vectorized=True, sequential=True),
          batch_max=64, batch_array=True)
    coord = Coordinator(g).start()
    try:
        coord.flakes["p"].pause()
        for i in range(n):
            coord.inject("p", float(i))
        coord.inject_landmark("p", tag="flush")
        for i in range(n, 2 * n):
            coord.inject("p", float(i))
        coord.flakes["p"].resume()
        assert coord.run_until_quiescent(timeout=60)
        kinds = [("lm" if m.landmark else float(m.payload))
                 for m in coord.drain_outputs()]
        assert kinds == [i * 2.0 for i in range(n)] + ["lm"] + \
            [i * 2.0 for i in range(n, 2 * n)]
    finally:
        coord.stop()


# -- routing -------------------------------------------------------------------

def test_array_hash_split_is_per_key_deterministic_and_fifo():
    """Carrier rows hash-split by the key sidecar: placement must equal the
    per-message HashSplit choice, and each key's values must arrive at its
    sink in injection order (per-key FIFO through array slicing)."""
    n, n_sinks = 400, 4
    g = FloeGraph("hash")
    g.add("src", lambda: FnPellet(lambda X: np.asarray(X), vectorized=True,
                                  sequential=True),
          batch_max=64, batch_array=True)
    for i in range(n_sinks):
        g.add(f"s{i}", lambda i=i: FnPellet(lambda x, i=i: (i, float(x)),
                                            sequential=True))
        g.connect("src", f"s{i}", split="hash")
    coord = Coordinator(g).start()
    try:
        coord.flakes["src"].pause()
        coord.inject_many("src", [float(i) for i in range(n)],
                          keys=[i % 8 for i in range(n)])
        coord.flakes["src"].resume()
        assert coord.run_until_quiescent(timeout=60)
        out = [m.payload for m in coord.drain_outputs() if m.is_data()]
        assert len(out) == n
        seen_per_key = {}
        for sink_idx, value in out:
            key = int(value) % 8
            assert sink_idx == stable_hash(key) % n_sinks
            seen_per_key.setdefault(key, []).append(value)
        for key, values in seen_per_key.items():
            assert values == sorted(values), f"key {key} out of order"
        assert not coord.errors, coord.errors[:3]
    finally:
        coord.stop()


def test_array_round_robin_matches_row_count():
    n = 128
    g = FloeGraph("rr")
    g.add("src", lambda: FnPellet(lambda X: np.asarray(X), vectorized=True,
                                  sequential=True),
          batch_max=32, batch_array=True)
    for i in range(2):
        g.add(f"s{i}", lambda i=i: FnPellet(lambda x, i=i: (i, float(x)),
                                            sequential=True))
        g.connect("src", f"s{i}", split="round_robin")
    coord = Coordinator(g).start()
    try:
        coord.flakes["src"].pause()
        coord.inject_many("src", [float(i) for i in range(n)])
        coord.flakes["src"].resume()
        assert coord.run_until_quiescent(timeout=60)
        out = [m.payload for m in coord.drain_outputs() if m.is_data()]
        assert len(out) == n
        per_sink = {0: 0, 1: 0}
        for sink_idx, _ in out:
            per_sink[sink_idx] += 1
        assert per_sink[0] == per_sink[1] == n // 2   # row-level RR
    finally:
        coord.stop()


def test_custom_split_sees_unstacked_rows():
    """A custom policy without a choose_rows path must observe every row
    as an ordinary Message (exact legacy semantics, no silent misroute)."""
    from repro.core import Split
    from repro.core.patterns import SPLITS

    class EvenOnly(Split):
        def choose(self, msg, n_edges, queue_depths):
            return [0] if int(msg.payload) % 2 == 0 else []

    SPLITS["even_only2"] = EvenOnly
    try:
        g = FloeGraph("csp")
        g.add("src", lambda: FnPellet(lambda X: np.asarray(X),
                                      vectorized=True, sequential=True),
              batch_max=32, batch_array=True)
        g.add("dst", lambda: FnPellet(lambda x: float(x), sequential=True))
        g.add("dst2", lambda: FnPellet(lambda x: float(x), sequential=True))
        g.connect("src", "dst", split="even_only2")
        g.connect("src", "dst2", split="even_only2")
        coord = Coordinator(g).start()
        try:
            coord.flakes["src"].pause()
            coord.inject_many("src", [float(i) for i in range(60)])
            coord.flakes["src"].resume()
            assert coord.run_until_quiescent(timeout=60)
            out = sorted(float(m.payload) for m in coord.drain_outputs()
                         if m.is_data())
            assert out == [float(i) for i in range(60) if i % 2 == 0]
        finally:
            coord.stop()
    finally:
        SPLITS.pop("even_only2", None)


# -- degradation ---------------------------------------------------------------

def test_array_failure_degrades_rowwise_zero_loss_zero_dup():
    """A raising compute_array degrades THAT batch to per-row compute:
    only the raising row drops (recorded), everything else delivers
    exactly once — the BatchItemError census."""
    def frag(X):
        arr = np.asarray(X)
        if arr.size > 1 and np.any(arr == 13):
            raise RuntimeError("vectorized boom")
        if np.any(arr == 13):
            raise RuntimeError("boom")
        return arr * 10.0

    n = 60
    g = FloeGraph("frag")
    g.add("p", lambda: FnPellet(frag, vectorized=True, sequential=True),
          batch_max=64, batch_array=True)
    coord = Coordinator(g).start()
    try:
        coord.flakes["p"].pause()
        coord.inject_many("p", [float(i) for i in range(n)])
        coord.flakes["p"].resume()
        assert coord.run_until_quiescent(timeout=60)
        out = sorted(float(m.payload) for m in coord.drain_outputs()
                     if m.is_data())
        assert out == [i * 10.0 for i in range(n) if i != 13]
        assert any(isinstance(e, RuntimeError) for _, e in coord.errors)
        st = coord.flakes["p"].stats
        assert st.arrived == st.processed == n   # credits exact, in rows
        assert st.emitted == n - 1
    finally:
        coord.stop()


def test_ragged_payloads_fall_back_to_rowwise_path():
    """Non-stackable payloads must silently take the row-wise batched
    path — correct results, no errors, no carriers."""
    n = 80
    calls = []

    def fn(xs):   # list contract: ragged batches arrive as lists
        calls.append(len(xs))
        return [sum(x) for x in xs]

    g = FloeGraph("rag")
    g.add("p", lambda: FnPellet(fn, vectorized=True, sequential=True),
          batch_max=32, batch_array=True)
    coord = Coordinator(g).start()
    try:
        coord.flakes["p"].pause()
        payloads = [[1] * (i % 5 + 1) for i in range(n)]   # ragged lists
        coord.inject_many("p", payloads)
        coord.flakes["p"].resume()
        assert coord.run_until_quiescent(timeout=60)
        out = sorted(int(m.payload) for m in coord.drain_outputs()
                     if m.is_data())
        assert out == sorted(i % 5 + 1 for i in range(n))
        assert not coord.errors, coord.errors[:3]
        assert sum(calls) == n      # still batched, just not columnar
    finally:
        coord.stop()


def test_carrier_unstacks_for_non_array_consumer():
    """An array stage feeding a window pellet: the carrier must degrade
    to per-row messages at the window's enqueue, keeping count-window
    semantics exact."""
    class SumWin(WindowPellet):
        window = 4

        def compute(self, payloads):
            return float(np.sum(np.asarray(payloads, dtype=np.float64)))

    n = 64
    g = FloeGraph("win")
    g.add("v", lambda: FnPellet(lambda X: np.asarray(X), vectorized=True,
                                sequential=True),
          batch_max=32, batch_array=True)
    g.add("w", SumWin)
    g.connect("v", "w")
    coord = Coordinator(g).start()
    try:
        coord.flakes["v"].pause()
        coord.inject_many("v", [float(i) for i in range(n)])
        coord.flakes["v"].resume()
        assert coord.run_until_quiescent(timeout=60)
        out = [float(m.payload) for m in coord.drain_outputs()
               if m.is_data()]
        assert len(out) == n // 4
        assert sum(out) == float(sum(range(n)))
        # windows gathered in row order: each is 4 consecutive values
        assert out[0] == 0.0 + 1 + 2 + 3
        assert not coord.errors, coord.errors[:3]
    finally:
        coord.stop()


def test_classic_list_result_ends_columnar_handoff_correctly():
    """An array=True stage whose callable returns a per-row LIST (the
    classic vectorized contract) still delivers exactly one result per
    row — the hand-off just stops being columnar at that stage."""
    n = 50
    g = FloeGraph("lst")
    g.add("p", lambda: FnPellet(lambda X: [float(x) * 3 for x in X],
                                vectorized=True, sequential=True),
          batch_max=32, batch_array=True)
    coord = Coordinator(g).start()
    try:
        coord.flakes["p"].pause()
        coord.inject_many("p", [float(i) for i in range(n)])
        coord.flakes["p"].resume()
        assert coord.run_until_quiescent(timeout=60)
        out = sorted(float(m.payload) for m in coord.drain_outputs()
                     if m.is_data())
        assert out == [i * 3.0 for i in range(n)]
        assert not coord.errors, coord.errors[:3]
    finally:
        coord.stop()


# -- Session API knob ----------------------------------------------------------

def test_flow_array_annotation_and_runtime_toggle():
    flow = Flow("knob")
    stage = flow.pellet("p", lambda: FnPellet(_vec, vectorized=True))
    stage.batch(32, array=True)
    with flow.session() as s:
        flake = s.coordinator.flakes["p"]
        assert flake.batch_array and flake.accepts_arrays
        assert s.stats()["p"]["batch_array"] is True
        s.set_batch("p", max_size=32, array=False)   # runtime opt-out
        assert not flake.batch_array
        s.set_batch("p", max_size=32, array=True)
        s.inject_many("p", [1.0, 2.0, 3.0])
        assert sorted(float(x) for x in s.results()) == [2.0, 4.0, 6.0]


# -- checkpoint / migration ----------------------------------------------------

def test_checkpoint_round_trips_parked_carrier(tmp_path):
    """A checkpoint taken with an ArrayBatch parked in a channel must
    restore and replay every row (carriers pickle via host arrays)."""
    flow = Flow("ck")
    flow.pellet("p", lambda: FnPellet(_vec, vectorized=True)) \
        .batch(64, array=True)
    path = str(tmp_path / "floe.ckpt")
    n = 40
    with flow.session() as s:
        flake = s.coordinator.flakes["p"]
        flake.pause()
        s.inject_many("p", [float(i) for i in range(n)])
        # force the backlog into carrier form: what an upstream array
        # stage would have parked here
        ch = flake.inputs["in"]
        msgs = ch.pop_up_to(None)
        ab = ArrayBatch.try_stack([m.payload for m in msgs],
                                  seqs=[m.seq for m in msgs])
        ch.put(Message(payload=ab))
        assert any(isinstance(m.payload, ArrayBatch) for m in ch._q)
        s.checkpoint(path)
    from repro.api.session import Session
    with Session.restore(path, flow) as s2:
        out = sorted(float(x) for x in s2.results())
        assert out == [i * 2.0 for i in range(n)]
        assert not s2.errors, s2.errors[:3]


def test_migration_carries_inflight_arraybatch():
    """Live flake migration with carriers parked in the channel: the
    columnar backlog moves host whole, zero loss / zero dup."""
    from repro.cluster import ClusterManager, ClusterSpec
    n = 256
    g = FloeGraph("mig")
    g.add("p0", lambda: FnPellet(lambda X: np.asarray(X), vectorized=True),
          cores=2, batch_max=64, batch_array=True)
    g.add("p1", lambda: FnPellet(_vec, vectorized=True),
          cores=2, batch_max=64, batch_array=True)
    g.connect("p0", "p1")
    cluster = ClusterManager(ClusterSpec(hosts=2, cores_per_host=8))
    coord = Coordinator(g, cluster=cluster).start()
    try:
        coord.flakes["p1"].pause()
        coord.flakes["p0"].pause()
        coord.inject_many("p0", [float(i) for i in range(n)])
        coord.flakes["p0"].resume()
        # wait until p0 pushed (stacked) batches into p1's channel
        assert wait_until(
            lambda: coord.flakes["p1"].queue_length() == n, timeout=30)
        assert any(isinstance(m.payload, ArrayBatch)
                   for m in coord.flakes["p1"].inputs["in"]._q)
        src = cluster.host_of("p1").name
        dst = "h1" if src == "h0" else "h0"
        cluster.migrate("p1", dst)
        assert cluster.host_of("p1").name == dst
        assert coord.flakes["p1"].batch_array    # knob survives the move
        assert coord.run_until_quiescent(timeout=60)
        out = [float(m.payload) for m in coord.drain_outputs()
               if m.is_data()]
        assert sorted(out) == [i * 2.0 for i in range(n)]
        assert len(out) == len(set(out)) == n    # 0 lost / 0 dup
        assert not coord.errors, coord.errors[:3]
    finally:
        coord.stop()
