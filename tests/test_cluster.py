"""Cluster runtime: hosts, placement, transports, live migration, VM-level
elasticity (simulated-VM deployment of the §III container model).

The load-bearing scenarios: migration correctness under load (per-key FIFO,
zero loss/duplication by payload census, landmark/window alignment
surviving a mid-window move) and the paper's scale-out arc — one host,
injected backlog, strategy-driven acquire + migrate to a second host,
drain, consolidate home, release the idle VM.
"""
import pickle
import time

import pytest

from repro import (ClusterError, ClusterManager, ClusterSpec, CompositionError,
                   Coordinator, FloeGraph, Flow, FnPellet, PullPellet,
                   SessionStateError, WindowPellet)
from repro.adaptation import AdaptationController, DynamicAdaptation
from repro.cluster import LoopbackTransport, SerializingTransport

from conftest import wait_until


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def chain_flow(n=3, fn=None, sequential=False):
    flow = Flow("chain")
    stages = []
    for i in range(n):
        f = fn or (lambda x: x)
        stages.append(flow.pellet(f"p{i}", (lambda f=f: FnPellet(
            f, sequential=sequential))))
        if i:
            stages[i - 1] >> stages[i]
    return flow, stages


# ---------------------------------------------------------------------------
# spec + fleet basics
# ---------------------------------------------------------------------------

def test_spec_validation():
    with pytest.raises(ClusterError):
        ClusterSpec(hosts=0)
    with pytest.raises(ClusterError):
        ClusterSpec(hosts=2, max_hosts=1)
    with pytest.raises(ClusterError):
        ClusterSpec(placement="nope")
    with pytest.raises(ClusterError):
        ClusterSpec(transport="udp")
    with pytest.raises(ClusterError):
        ClusterSpec(spinup_s=-1)


def test_quota_and_release_rules():
    cm = ClusterManager(ClusterSpec(hosts=1, cores_per_host=4, max_hosts=2))
    h1 = cm.acquire_host()
    assert h1.elastic
    with pytest.raises(ClusterError):
        cm.acquire_host()                    # quota: 2 active
    cm.release_host(h1)
    assert h1.state == "released"
    cm.release_host(h1)                      # idempotent
    h2 = cm.acquire_host()                   # slot freed
    assert h2.name == "h2"


def test_spinup_latency_is_respected():
    cm = ClusterManager(ClusterSpec(hosts=1, cores_per_host=2, spinup_s=0.3))
    assert cm.hosts["h0"].is_ready           # initial fleet: ready at once
    t0 = time.time()
    h = cm.acquire_host()                    # elastic: pays spin-up
    assert not h.is_ready and h.state == "provisioning"
    h.wait_ready()
    assert time.time() - t0 >= 0.29 and h.is_ready
    with pytest.raises(TimeoutError):
        cm.acquire_host().wait_ready(timeout=0.01)


def test_release_refuses_occupied_host():
    flow, (a, b, c) = chain_flow()
    with flow.session(cluster=ClusterSpec(hosts=2, cores_per_host=8)) as s:
        host = s.cluster.host_of("p0")
        with pytest.raises(ClusterError):
            s.cluster.release_host(host)


# ---------------------------------------------------------------------------
# placement: policies + annotations
# ---------------------------------------------------------------------------

def test_bin_pack_vs_spread():
    g = FloeGraph("g")
    for i in range(4):
        g.add(f"p{i}", lambda: FnPellet(lambda x: x), cores=2)
    packed = ClusterManager(ClusterSpec(hosts=2, cores_per_host=8))
    packed.place_all(g, list(g.vertices))
    assert set(packed._placement.values()) == {"h0"}   # best fit packs
    spread = ClusterManager(ClusterSpec(hosts=2, cores_per_host=8,
                                        placement="spread"))
    spread.place_all(g, list(g.vertices))
    by_host = {}
    for f, h in spread._placement.items():
        by_host.setdefault(h, []).append(f)
    assert len(by_host) == 2 and all(len(v) == 2 for v in by_host.values())


def test_place_and_colocate_annotations():
    flow = Flow("placed")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x)).place(host="h1")
    b = flow.pellet("b", lambda: FnPellet(lambda x: x)).place(
        colocate_with=a)
    c = flow.pellet("c", lambda: FnPellet(lambda x: x)).place(
        colocate_with="b")                   # chain resolves through b -> a
    a >> b >> c
    with flow.session(cluster=ClusterSpec(hosts=2, cores_per_host=8)) as s:
        assert s.describe()["cluster"]["placement"] == {
            "a": "h1", "b": "h1", "c": "h1"}


def test_place_validation_errors():
    flow = Flow("bad")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x))
    with pytest.raises(CompositionError):
        a.place()                            # neither
    with pytest.raises(CompositionError):
        a.place(host="h0", colocate_with="a")  # both
    with pytest.raises(CompositionError):
        a.place(colocate_with="missing")
    with pytest.raises(CompositionError):
        a.place(colocate_with=a)
    other = Flow("other").pellet("x", lambda: FnPellet(lambda x: x))
    with pytest.raises(CompositionError):
        a.place(colocate_with=other)


def test_oversubscribe_fallback_recorded():
    g = FloeGraph("g")
    g.add("big", lambda: FnPellet(lambda x: x), cores=8)
    cm = ClusterManager(ClusterSpec(hosts=1, cores_per_host=2))
    cm.place_all(g, ["big"])
    assert any(e["event"] == "oversubscribe" for e in cm.events)
    assert cm.hosts["h0"].free_cores < 0     # honest accounting


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------

def test_loopback_counts_cross_host_traffic_only():
    flow = Flow("x")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x)).place(host="h0")
    b = flow.pellet("b", lambda: FnPellet(lambda x: x)).place(host="h1")
    c = flow.pellet("c", lambda: FnPellet(lambda x: x)).place(host="h1")
    a >> b >> c                              # a->b crosses, b->c is local
    with flow.session(cluster=ClusterSpec(hosts=2, cores_per_host=4)) as s:
        s.inject_many(a, list(range(50)))
        assert len(s.results()) == 50
        t = s.cluster.transport.stats
        assert t.messages == 50 and t.bytes == 0


def test_serializing_transport_roundtrips_payloads():
    flow = Flow("ser")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x)).place(host="h0")
    b = flow.pellet("b", lambda: FnPellet(lambda x: x)).place(host="h1")
    a >> b
    spec = ClusterSpec(hosts=2, cores_per_host=4, transport="serializing")
    with flow.session(cluster=spec) as s:
        payload = {"k": [1, 2]}
        s.inject(a, payload)
        out = s.drain()
        got = [m.payload for m in out if m.is_data()][0]
        # equal but never the same object: no sharing across hosts
        assert got == payload and got is not payload
        assert got["k"] is not payload["k"]
        assert s.cluster.transport.stats.bytes > 0


def test_serializing_transport_enforces_picklability():
    flow = Flow("ser2")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x)).place(host="h0")
    b = flow.pellet("b", lambda: FnPellet(lambda x: x)).place(host="h1")
    a >> b
    spec = ClusterSpec(hosts=2, cores_per_host=4, transport="serializing")
    with flow.session(cluster=spec) as s:
        s.inject(a, lambda: None)            # not picklable
        assert s.quiesce(10)                 # credits released, no wedge
        assert s.errors and s.errors[-1][0] == "a"
        assert isinstance(s.errors[-1][1], (pickle.PicklingError,
                                            AttributeError, TypeError))


def test_serializing_transport_models_delay():
    t = SerializingTransport(per_msg_delay_s=0.01, per_byte_delay_s=0.0)

    class Sink:
        def enqueue_many(self, port, msgs):
            self.got = msgs

    from repro.core.message import Message
    sink = Sink()
    t0 = time.time()
    t.deliver(sink, "in", [Message(payload=i) for i in range(3)])
    assert time.time() - t0 >= 0.03
    assert t.stats.modeled_delay_s >= 0.03 and t.stats.messages == 3


# ---------------------------------------------------------------------------
# live migration
# ---------------------------------------------------------------------------

def test_migrate_mid_stream_zero_loss_zero_dup():
    flow, (p0, p1, p2) = chain_flow(3, fn=lambda x: x)
    with flow.session(cluster=ClusterSpec(hosts=2, cores_per_host=8)) as s:
        n = 2000
        s.inject_many(p0, list(range(n)))
        src = s.cluster.host_of("p1").name
        s.migrate(p1, "h1" if src == "h0" else "h0")
        out = s.results()
        assert len(out) == n and len(set(out)) == n    # census: exact
        assert not s.errors


def test_migrate_under_load_preserves_per_key_fifo():
    seen = []
    flow = Flow("fifo")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x, sequential=True))
    mid = flow.pellet("mid", lambda: FnPellet(lambda x: x, sequential=True))
    snk = flow.pellet("snk", lambda: FnPellet(
        lambda kv: (seen.append(kv), kv)[1], sequential=True))
    src >> mid >> snk
    keys, per_key = 4, 250
    with flow.session(cluster=ClusterSpec(hosts=2, cores_per_host=8)) as s:
        payloads = [(i % keys, i // keys) for i in range(keys * per_key)]
        s.inject_many(src, payloads, keys=[p[0] for p in payloads])
        s.migrate(mid, "h1" if s.cluster.host_of("mid").name == "h0"
                  else "h0")
        out = s.results()
        assert len(out) == keys * per_key and len(set(seen)) == len(seen)
        for k in range(keys):                # FIFO per key across the move
            ordered = [i for kk, i in seen if kk == k]
            assert ordered == sorted(ordered)


def test_migrate_carries_pull_pellet_state():
    class Counter(PullPellet):
        def compute(self, messages, emit, state):
            state = state or 0
            for m in messages:
                state += 1
                emit(state)
            return state

    flow = Flow("state")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x, sequential=True))
    cnt = flow.pellet("cnt", Counter)
    src >> cnt
    with flow.session(cluster=ClusterSpec(hosts=2, cores_per_host=8)) as s:
        s.inject_many(src, list(range(10)))
        assert sorted(s.results()) == list(range(1, 11))
        s.migrate(cnt, "h1" if s.cluster.host_of("cnt").name == "h0"
                  else "h0")
        s.inject_many(src, list(range(5)))
        # the running count survives the move: 11..15, not 1..5
        assert sorted(s.results()) == list(range(11, 16))


def test_migrate_mid_window_keeps_partial_window():
    class SumWindow(WindowPellet):
        window = 5

        def compute(self, payloads):
            return sum(payloads)

    flow = Flow("win")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x, sequential=True))
    win = flow.pellet("win", SumWindow)
    src >> win
    with flow.session(cluster=ClusterSpec(hosts=2, cores_per_host=8)) as s:
        for x in (1, 2, 3):
            s.inject(src, x)
        flake = s.coordinator.flakes["win"]
        assert wait_until(lambda: len(flake._window_buf) == 3
                          and flake.queue_length() == 0)
        s.migrate(win, "h1")
        s.inject(src, 4)
        s.inject(src, 5)                     # completes the window post-move
        assert s.results() == [15]
        # landmark flushes a partial window on the migrated flake
        s.inject(src, 7)
        s.inject_landmark(src)
        out = s.drain()
        assert [m.payload for m in out if m.is_data()] == [7]


def test_migrate_preserves_landmark_alignment_round():
    flow = Flow("align")
    s1 = flow.pellet("s1", lambda: FnPellet(lambda x: x, sequential=True))
    s2 = flow.pellet("s2", lambda: FnPellet(lambda x: x, sequential=True))
    mid = flow.pellet("mid", lambda: FnPellet(lambda x: x))
    s1 >> mid
    s2 >> mid                                # fan-in 2: landmarks align
    with flow.session(cluster=ClusterSpec(hosts=2, cores_per_host=8)) as s:
        s.inject_landmark(s1)                # first copy: swallowed
        assert s.quiesce(10)
        assert s.coordinator.flakes["mid"]._lm_count == 1
        s.migrate(mid, "h1")
        s.inject_landmark(s2)                # second copy completes the round
        out = s.drain()
        assert sum(1 for m in out if m.landmark) == 1


@pytest.mark.timeout(110)
def test_inject_racing_migration_loses_nothing():
    """Injection concurrent with repeated migrations: exact census, and
    the session still quiesces (no stranded inflight credits)."""
    import threading

    flow, (p0, p1, p2) = chain_flow(3, fn=lambda x: x)
    with flow.session(cluster=ClusterSpec(hosts=2, cores_per_host=8)) as s:
        n, chunks = 20_000, 200
        stop = threading.Event()

        def injector():
            for i in range(0, n, chunks):
                s.inject_many(p0, list(range(i, i + chunks)))

        t = threading.Thread(target=injector)
        t.start()
        for i in range(12):
            s.migrate(p1, "h1" if s.cluster.host_of("p1").name == "h0"
                      else "h0")
        t.join()
        stop.set()
        out = s.results(timeout=60)
        assert len(out) == n and len(set(out)) == n
        assert not s.errors


def test_prebuilt_manager_survives_session_close():
    """A prebuilt ClusterManager is reusable: placements clear on close,
    the fleet and its ledger survive."""
    cm = ClusterManager(ClusterSpec(hosts=2, cores_per_host=8))
    for round_ in range(2):
        flow, (p0, p1, p2) = chain_flow(3, fn=lambda x: x + 1)
        with flow.session(cluster=cm) as s:
            s.inject_many(p0, list(range(10)))
            assert sorted(s.results()) == [i + 3 for i in range(10)]
        assert cm._placement == {} and cm._coord is None
    assert len(cm.hosts) == 2                # same fleet both rounds
    # while a session is live, a second bind is refused
    flow2, _ = chain_flow(2)
    with flow2.session(cluster=cm):
        flow3, _ = chain_flow(2)
        with pytest.raises(ClusterError):
            flow3.session(cluster=cm).open()


def test_release_host_refuses_pending_scaleout_target():
    cm = ClusterManager(ClusterSpec(hosts=1, cores_per_host=4, max_hosts=2))
    h = cm.acquire_host()
    cm._pending["work"] = h.name             # scale-out awaiting spin-up
    with pytest.raises(ClusterError):
        cm.release_host(h)
    cm._pending.clear()
    cm.release_host(h)                       # releasable once cancelled


def test_migrate_requires_cluster_and_known_host():
    flow, (p0, p1, p2) = chain_flow()
    with flow.session() as s:
        with pytest.raises(SessionStateError):
            s.migrate(p1, "h1")
    g = Flow("g")
    a = g.pellet("a", lambda: FnPellet(lambda x: x))
    with g.session(cluster=ClusterSpec(hosts=1, cores_per_host=4)) as s:
        with pytest.raises(ClusterError):
            s.migrate(a, "h9")


# ---------------------------------------------------------------------------
# core accounting: release-on-deactivate / release-on-migrate audit
# ---------------------------------------------------------------------------

def test_cores_released_on_session_close_legacy_and_cluster():
    flow, _ = chain_flow()
    s = flow.session()
    s.open()
    coord = s.coordinator
    assert coord.core_audit()                # allocations live while running
    s.close()
    assert coord.core_audit() == {}          # all returned on deactivate

    flow2, _ = chain_flow()
    s2 = flow2.session(cluster=ClusterSpec(hosts=2, cores_per_host=8))
    s2.open()
    coord2 = s2.coordinator
    s2.close()
    assert coord2.core_audit() == {}


def test_migrate_moves_core_accounting():
    flow, (p0, p1, p2) = chain_flow()
    with flow.session(cluster=ClusterSpec(hosts=2, cores_per_host=8)) as s:
        src = s.cluster.host_of("p1")
        dst = s.cluster.hosts["h1" if src.name == "h0" else "h0"]
        s.migrate(p1, dst.name, cores=3)
        assert "p1" not in src.container.allocated
        assert dst.container.allocated["p1"] == 3
        assert s.cores(p1) == 3
        assert not s.errors                  # no accounting-drift error


def test_cluster_scale_is_bounded_by_host():
    flow = Flow("bounded")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x))
    with flow.session(cluster=ClusterSpec(hosts=1, cores_per_host=4)) as s:
        s.scale(a, cores=16)                 # intra-VM resize: capped
        assert s.cores(a) == 4
        assert s.cluster.hosts["h0"].free_cores == 0
        s.scale(a, cores=1)
        assert s.cluster.hosts["h0"].free_cores == 3


# ---------------------------------------------------------------------------
# observation plumbing (batch occupancy -> adaptation layer)
# ---------------------------------------------------------------------------

def test_observation_carries_batch_occupancy():
    flow = Flow("obs")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x, sequential=True))
    work = flow.pellet("work", lambda: FnPellet(lambda x: x)).batch(64)
    src >> work
    with flow.session() as s:
        ctrl = AdaptationController(s.coordinator,
                                    {"work": DynamicAdaptation()})
        s.inject_many(src, list(range(2000)))
        assert len(s.results()) == 2000
        ctrl.step_once()
        obs = ctrl.history[-1][2]
        assert obs.last_batch >= 1
        assert obs.avg_batch > 0.0
        st = s.stats()["work"]
        assert st["avg_batch"] > 0.0 and st["last_batch"] >= 1


def test_inject_many_validates_keys():
    flow, (p0, p1, p2) = chain_flow()
    with flow.session() as s:
        with pytest.raises(ValueError):
            s.inject_many(p0, [1, 2, 3], keys=[1])


# ---------------------------------------------------------------------------
# the scripted scale-out scenario (acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.timeout(110)
def test_scaleout_scenario_end_to_end():
    """1 host -> backlog -> strategy acquires + migrates to a 2nd host ->
    drain with exact census -> consolidate home -> idle host released."""
    def busy(x):
        time.sleep(0.001)
        return x

    flow = Flow("scenario")
    gen = flow.pellet("gen", lambda: FnPellet(lambda x: x, sequential=True))
    work = flow.pellet("work", lambda: FnPellet(busy), cores=1)
    snk = flow.pellet("snk", lambda: FnPellet(lambda x: x))
    gen >> work >> snk
    work.elastic(max_cores=8, drain_horizon=0.3)
    spec = ClusterSpec(hosts=1, cores_per_host=3, max_hosts=2,
                       spinup_s=0.05, idle_grace_s=0.1)
    n = 2000
    with flow.session(cluster=spec, sample_interval=0.02) as s:
        s.inject_many(gen, list(range(n)))
        # strategy-driven scale-out: a second VM is acquired and the hot
        # stage live-migrates onto it while traffic flows
        assert wait_until(
            lambda: s.cluster._placement.get("work") == "h1", timeout=60)
        assert s.cluster.hosts["h1"].elastic
        out = s.results(timeout=90)
        assert len(out) == n and len(set(out)) == n    # zero loss, zero dup
        assert not s.errors
        # burst over: consolidate home, release the idle VM
        assert wait_until(
            lambda: s.cluster.hosts["h1"].state == "released", timeout=30)
        assert s.cluster._placement["work"] == "h0"
        kinds = [e["event"] for e in s.cluster.events]
        assert kinds.count("acquire") >= 2 and "migrate" in kinds \
            and "release" in kinds
        assert s.cluster.host_seconds() > 0
        assert s.cluster.transport.stats.messages > 0  # edges crossed hosts
    # post-close: nothing leaked
    assert s._coord is None
