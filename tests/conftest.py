"""Shared test configuration: per-test ceilings + JAX compile cache.

* Every test runs under a wall-clock ceiling (default 120 s) enforced with a
  SIGALRM watchdog, so a hung dataflow fails fast instead of wedging CI.
  Override per test with ``@pytest.mark.timeout(seconds)`` — the marker is
  compatible with pytest-timeout, which takes over transparently when
  installed (we then skip the built-in watchdog).
* The JAX persistent compilation cache is enabled (repo-local
  ``.jax_cache/``): the model/kernel smoke tests are dominated by XLA
  compilation, so warm reruns and cached CI runs cut minutes of wall time.
"""
import math
import os
import pathlib
import signal
import threading

import pytest

# -- JAX persistent compilation cache (must be set before jax imports) -------
_CACHE = pathlib.Path(__file__).resolve().parent.parent / ".jax_cache"
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", str(_CACHE))
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0.5")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES", "-1")

DEFAULT_TIMEOUT_S = 120.0

try:
    import pytest_timeout  # noqa: F401
    _HAVE_PYTEST_TIMEOUT = True
except ImportError:
    _HAVE_PYTEST_TIMEOUT = False


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "timeout(seconds): per-test wall-clock ceiling (watchdog)")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    """SIGALRM-based per-test ceiling (pytest-timeout fallback).

    Only active on the main thread of a POSIX process; elsewhere (or when
    the real pytest-timeout plugin is installed) it steps aside.
    """
    marker = item.get_closest_marker("timeout")
    seconds = float(marker.args[0]) if marker and marker.args \
        else DEFAULT_TIMEOUT_S
    usable = (not _HAVE_PYTEST_TIMEOUT
              and hasattr(signal, "SIGALRM")
              and threading.current_thread() is threading.main_thread()
              and seconds > 0)
    if not usable:
        yield
        return

    def _on_alarm(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded the {seconds:.0f}s per-test ceiling")

    old_handler = signal.signal(signal.SIGALRM, _on_alarm)
    signal.alarm(max(1, math.ceil(seconds)))
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old_handler)


# -- polling helpers (replace sleep-based waits in dataflow tests) ------------

def wait_until(predicate, *, timeout: float = 10.0,
               interval: float = 0.005) -> bool:
    """Poll ``predicate`` until truthy or ``timeout``; returns the verdict.

    Use instead of fixed ``time.sleep`` so tests advance the moment the
    engine reaches the awaited state.
    """
    import time
    deadline = time.time() + timeout
    while time.time() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return bool(predicate())
