"""Application dynamism (paper §II.B): dynamic task + dataflow updates."""
import threading
import time

import pytest

from conftest import wait_until
from repro.core import (Coordinator, FloeGraph, FnPellet, Message, PullPellet,
                        PushPellet)


class V1(PushPellet):
    def compute(self, x):
        return ("v1", x)


class V2(PushPellet):
    def compute(self, x):
        return ("v2", x)


def test_sync_task_update_swaps_logic():
    g = FloeGraph("upd")
    g.add("p", V1)
    coord = Coordinator(g).start()
    try:
        coord.inject("p", 1)
        assert coord.run_until_quiescent(timeout=30)
        coord.update_pellet("p", V2, mode="sync")
        coord.inject("p", 2)
        assert coord.run_until_quiescent(timeout=30)
        out = [m.payload for m in coord.drain_outputs() if m.is_data()]
        assert out == [("v1", 1), ("v2", 2)]
        assert coord.flakes["p"].version == 1
    finally:
        coord.stop()


def test_sync_update_drains_inflight_first():
    """Synchronous update: messages being processed finish to completion and
    their outputs are delivered before the new pellet is instantiated."""
    release = threading.Event()

    class Slow(PushPellet):
        def compute(self, x):
            release.wait(timeout=10)
            return ("old", x)

    g = FloeGraph("upd2")
    g.add("p", Slow, cores=2)
    coord = Coordinator(g).start()
    try:
        for i in range(4):
            coord.inject("p", i)
        # let all 4 instances pick up their message and block on the gate
        assert wait_until(lambda: coord.flakes["p"]._inflight == 4)

        done = threading.Event()

        def do_update():
            coord.update_pellet("p", V2, mode="sync")
            done.set()

        t = threading.Thread(target=do_update, daemon=True)
        t.start()
        time.sleep(0.1)
        assert not done.is_set()  # update is blocked on the drain
        release.set()
        t.join(timeout=20)
        assert done.is_set()
        assert coord.run_until_quiescent(timeout=30)
        out = [m.payload for m in coord.drain_outputs() if m.is_data()]
        # every message processed exactly once; old before the swap
        assert sorted(out) == [("old", i) for i in range(4)]
    finally:
        release.set()
        coord.stop()


def test_async_update_zero_downtime_interleaves():
    """Asynchronous update: old in-flight instances run to completion while
    the new logic processes new messages — outputs may interleave."""
    gate = threading.Event()

    class SlowV1(PushPellet):
        def compute(self, x):
            gate.wait(timeout=10)
            return ("v1", x)

    g = FloeGraph("upd3")
    g.add("p", SlowV1, cores=2)
    coord = Coordinator(g).start()
    try:
        coord.inject("p", 0)
        # old instance now in flight, blocked on the gate
        assert wait_until(lambda: coord.flakes["p"]._inflight == 1)
        coord.update_pellet("p", V2, mode="async")  # returns immediately
        coord.inject("p", 1)
        # new logic processes msg 1 while the old instance is still blocked
        assert wait_until(lambda: any(m.payload == ("v2", 1)
                                      for m in coord.outputs))
        gate.set()
        assert coord.run_until_quiescent(timeout=30)
        out = {m.payload for m in coord.drain_outputs() if m.is_data()}
        assert out == {("v1", 0), ("v2", 1)}
    finally:
        gate.set()
        coord.stop()


def test_update_emits_update_landmark():
    g = FloeGraph("upd4")
    g.add("p", V1)
    g.add("sink", lambda: FnPellet(lambda x: x))
    g.connect("p", "sink")
    coord = Coordinator(g).start()
    try:
        coord.update_pellet("p", V2, mode="sync", emit_update_landmark=True)
        assert coord.run_until_quiescent(timeout=30)
        lms = [m for m in coord.drain_outputs() if m.update_landmark]
        assert lms and lms[0].payload["version"] == 1
    finally:
        coord.stop()


def test_update_rejects_port_mismatch():
    class TwoPort(PushPellet):
        out_ports = ("a", "b")

        def compute(self, x):
            return {"a": x}

    g = FloeGraph("upd5")
    g.add("p", V1)
    coord = Coordinator(g).start()
    try:
        with pytest.raises(ValueError, match="identical ports"):
            coord.update_pellet("p", TwoPort)
    finally:
        coord.stop()


def test_stateful_pellet_state_survives_update():
    """Internal state held by a stateful pellet survives the update (§II.B)."""
    class CounterA(PullPellet):
        def initial_state(self):
            return 0

        def compute(self, messages, emit, state):
            for m in messages:
                if m.is_data():
                    state += m.payload
                    emit(("a", state))
            return state

    class CounterB(CounterA):
        def compute(self, messages, emit, state):
            for m in messages:
                if m.is_data():
                    state += m.payload
                    emit(("b", state))
            return state

    g = FloeGraph("upd6")
    g.add("p", CounterA)
    coord = Coordinator(g).start()
    try:
        coord.inject("p", 5)
        assert coord.run_until_quiescent(timeout=30)
        coord.update_pellet("p", CounterB, mode="sync")
        coord.inject("p", 3)
        assert coord.run_until_quiescent(timeout=30)
        out = [m.payload for m in coord.drain_outputs() if m.is_data()]
        assert out == [("a", 5), ("b", 8)]  # 8 = state 5 survived + 3
    finally:
        coord.stop()


def test_pending_messages_survive_update():
    """Messages pending in input ports are retained for the new pellet."""
    g = FloeGraph("upd7")
    g.add("gate", lambda: FnPellet(lambda x: x, sequential=True))
    g.add("p", V1)
    g.connect("gate", "p")
    coord = Coordinator(g).start()
    try:
        coord.flakes["p"].pause()
        coord.inject("gate", 1)
        coord.inject("gate", 2)
        # messages flow through the gate and park in p's input queue
        assert wait_until(lambda: coord.flakes["p"].queue_length() == 2)
        coord.update_pellet("p", V2, mode="async")
        coord.flakes["p"].resume()
        assert coord.run_until_quiescent(timeout=30)
        out = [m.payload for m in coord.drain_outputs() if m.is_data()]
        assert sorted(out) == [("v2", 1), ("v2", 2)]
    finally:
        coord.stop()


def test_dynamic_dataflow_subgraph_update():
    """Coordinated multi-pellet swap (§II.B dynamic dataflow update)."""
    g = FloeGraph("sub")
    g.add("a", V1)
    g.add("b", V1)
    g.add("join", lambda: FnPellet(lambda x: x))
    g.connect("a", "join")
    g.connect("b", "join")
    coord = Coordinator(g).start()
    try:
        coord.inject("a", 1)
        coord.inject("b", 2)
        assert coord.run_until_quiescent(timeout=30)
        coord.update_subgraph({"a": V2, "b": V2}, mode="sync")
        coord.inject("a", 3)
        coord.inject("b", 4)
        assert coord.run_until_quiescent(timeout=30)
        out = [m.payload for m in coord.drain_outputs() if m.is_data()]
        assert sorted(out) == [("v1", 1), ("v1", 2), ("v2", 3), ("v2", 4)]
        assert coord.flakes["a"].version == 1
        assert coord.flakes["b"].version == 1
    finally:
        coord.stop()


def test_set_cores_runtime_resource_control():
    g = FloeGraph("cores")
    g.add("p", lambda: FnPellet(lambda x: x), cores=1)
    coord = Coordinator(g).start()
    try:
        assert coord.flakes["p"].cores == 1
        coord.set_cores("p", 4)
        assert coord.flakes["p"].cores == 4
        assert coord.flakes["p"]._sem.capacity == 16  # alpha = 4
        coord.inject("p", 1)
        assert coord.run_until_quiescent(timeout=30)
        assert [m.payload for m in coord.drain_outputs()] == [1]
    finally:
        coord.stop()


def test_speculative_execution_dedups():
    """Straggler mitigation: backup task fires; output delivered exactly once."""
    calls = []
    lock = threading.Lock()

    class Straggler(PushPellet):
        def compute(self, x):
            with lock:
                calls.append(x)
                first = calls.count(x) == 1
            if first and x == 0:
                time.sleep(0.25)  # straggle on the first attempt only
            return ("ok", x)

    g = FloeGraph("spec")
    g.add("p", Straggler, cores=2)
    coord = Coordinator(g, speculative_timeout=0.05).start()
    try:
        coord.inject("p", 0)
        coord.inject("p", 1)
        # the backup task fires after the speculative timeout
        assert wait_until(lambda: calls.count(0) >= 2, timeout=10)
        assert coord.run_until_quiescent(timeout=30)
        out = [m.payload for m in coord.drain_outputs() if m.is_data()]
        assert sorted(out) == [("ok", 0), ("ok", 1)]  # exactly once each
        assert calls.count(0) >= 2  # the backup task really ran
    finally:
        coord.stop()
