"""BSP pattern tests (paper §II.A, Fig. 1 P10): supersteps, gating, halting."""
import pytest

from repro.core import Coordinator, FloeGraph, FnPellet, add_bsp, start_bsp


def run_bsp(n_workers, logic, init_states=None, seeds=None, max_supersteps=50):
    g = FloeGraph("bsp")
    g.add("sink", lambda: FnPellet(lambda x: x))
    workers, mgr = add_bsp(g, prefix="bsp", n_workers=n_workers, logic=logic,
                           init_states=init_states,
                           max_supersteps=max_supersteps, sink="sink")
    coord = Coordinator(g).start()
    try:
        start_bsp(coord, workers, seeds=seeds)
        assert coord.run_until_quiescent(timeout=60)
        assert not coord.errors, coord.errors
        states = [coord.flakes[w].state["user"] for w in workers]
        results = [m.payload for m in coord.drain_outputs() if m.is_data()]
        return states, results
    finally:
        coord.stop()


def test_bsp_fixed_supersteps():
    """Each worker increments a counter for 5 supersteps, then halts."""
    def logic(wid, step, state, inbox):
        state = (state or 0) + 1
        return state, [], state >= 5

    states, results = run_bsp(3, logic)
    assert states == [5, 5, 5]
    assert results and results[0]["supersteps"] == 5
    assert results[0]["halted"] is True


def test_bsp_superstep_barrier_visibility():
    """Messages sent in superstep k are visible only in superstep k+1."""
    n = 3
    trace = {i: [] for i in range(n)}

    def logic(wid, step, state, inbox):
        trace[wid].append((step, sorted(inbox)))
        # everyone sends its id to everyone (incl. self) for 3 steps
        out = [(dst, (step, wid)) for dst in range(n)] if step < 3 else []
        return state, out, step >= 3

    run_bsp(n, logic)
    for wid in range(n):
        steps = dict(trace[wid])
        assert steps[0] == []                            # nothing yet
        for k in (1, 2, 3):
            # inbox at step k = messages emitted at step k-1 by all workers
            assert steps[k] == sorted((k - 1, w) for w in range(n))


def test_bsp_max_iterations_global_max():
    """Distributed max: workers exchange values until fixpoint (runtime-
    decided superstep count, the paper's BSP requirement)."""
    init = [3, 9, 4, 7]
    n = len(init)

    def logic(wid, step, state, inbox):
        cur = state
        new = max([cur] + [v for v in inbox])
        changed = (new != cur) or step == 0
        out = [(dst, new) for dst in range(n) if dst != wid] if changed else []
        return new, out, not changed

    states, results = run_bsp(n, logic, init_states=init)
    assert states == [9, 9, 9, 9]
    assert results[0]["halted"] is True
    assert results[0]["supersteps"] <= 6


def test_bsp_seeded_inbox():
    """start_bsp seeds worker inboxes as superstep-0 data."""
    def logic(wid, step, state, inbox):
        total = (state or 0) + sum(inbox)
        return total, [], True  # single superstep

    states, _ = run_bsp(2, logic, seeds={0: [10, 20], 1: [5]})
    assert states == [30, 5]


def test_bsp_runaway_capped():
    def logic(wid, step, state, inbox):
        return state, [(0, "ping")], False  # never halts

    _, results = run_bsp(2, logic, max_supersteps=7)
    assert results and results[0]["supersteps"] == 7
    assert results[0]["halted"] is False
