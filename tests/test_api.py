"""Session API: fluent composition, sessions, transactional recomposition,
and declarative elasticity (ISSUE 1 tentpole)."""
import threading
import time

import pytest

from repro import (CompositionError, Coordinator, Drop, FloeGraph, Flow,
                   FnPellet, FnMapper, FnReducer, PushPellet,
                   RecompositionError, SessionStateError, TuplePellet)


class Switch(PushPellet):
    out_ports = ("small", "large")

    def compute(self, x):
        return {"small": x} if x < 50 else {"large": x}


class Tag(PushPellet):
    def __init__(self, tag="v1"):
        self.tag = tag

    def compute(self, x):
        return (self.tag, x)


# ---------------------------------------------------------------------------
# eager composition-time validation
# ---------------------------------------------------------------------------

def test_unknown_port_rejected_at_subscript():
    flow = Flow("t")
    sw = flow.pellet("sw", Switch)
    with pytest.raises(CompositionError, match="no port 'typo'"):
        sw["typo"]


def test_connect_to_output_port_rejected():
    """Direction typing: an out-port cannot be used as a sink."""
    flow = Flow("t")
    sw = flow.pellet("sw", Switch)
    sink = flow.pellet("sink", lambda: FnPellet(lambda x: x))
    with pytest.raises(CompositionError, match="no INPUT port 'large'"):
        sink >> sw["large"]


def test_connect_from_input_port_rejected():
    flow = Flow("t")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x))
    b = flow.pellet("b", lambda: FnPellet(lambda x: x))
    with pytest.raises(CompositionError, match="no OUTPUT port 'in'"):
        a["in"] >> b


def test_unknown_split_rejected_eagerly():
    flow = Flow("t")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x))
    with pytest.raises(CompositionError, match="unknown split 'sharded'"):
        a.split("sharded")


def test_conflicting_splits_on_one_fanout_group_rejected():
    flow = Flow("t")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x))
    b = flow.pellet("b", lambda: FnPellet(lambda x: x))
    c = flow.pellet("c", lambda: FnPellet(lambda x: x))
    a.split("hash") >> b
    with pytest.raises(CompositionError, match="conflicting splits"):
        a.split("duplicate") >> c


def test_duplicate_stage_name_rejected():
    flow = Flow("t")
    flow.pellet("a", lambda: FnPellet(lambda x: x))
    with pytest.raises(CompositionError, match="duplicate stage"):
        flow.pellet("a", lambda: FnPellet(lambda x: x))


def test_multi_out_stage_requires_explicit_port():
    flow = Flow("t")
    sw = flow.pellet("sw", Switch)
    sink = flow.pellet("sink", lambda: FnPellet(lambda x: x))
    with pytest.raises(CompositionError, match="multiple output ports"):
        sw >> sink


def test_sync_merge_fanin_gap_rejected_at_build():
    class Join(TuplePellet):
        in_ports = ("left", "right")

        def compute(self, inputs):
            return inputs

    flow = Flow("t")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x))
    j = flow.pellet("join", Join)
    a >> j["left"]                      # "right" never fed
    with pytest.raises(CompositionError, match="stall alignment"):
        flow.build()


def test_bad_elastic_policy_rejected_eagerly():
    flow = Flow("t")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x))
    with pytest.raises(CompositionError, match="unknown elasticity strategy"):
        a.elastic(strategy="magic")
    with pytest.raises(CompositionError, match="static hints"):
        a.elastic(strategy="static")
    with pytest.raises(CompositionError, match="window_duration"):
        a.elastic(strategy="static", latency=1.0,
                  expected_window_messages=10, window_duration=0.0)


def test_static_policy_respects_max_cores():
    from repro.api.policies import ElasticPolicy
    strat = ElasticPolicy(strategy="static", max_cores=4, latency=2.0,
                          expected_window_messages=400,
                          window_duration=1.0).build_strategy()
    assert strat.cores == 4          # uncapped formula would demand 200


def test_flow_compiles_to_floegraph():
    flow = Flow("compile")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x), cores=2)
    sw = flow.pellet("sw", Switch)
    sink = flow.pellet("sink", lambda: FnPellet(lambda x: x))
    src >> sw
    sw["small"] >> sink
    sw["large"].split("hash") >> sink
    g = flow.build()
    assert isinstance(g, FloeGraph)
    assert set(g.vertices) == {"src", "sw", "sink"}
    assert g.vertices["src"].cores == 2
    (large_edge,) = g.out_edges("sw", "large")
    assert large_edge.split == "hash"
    # the compiled graph still runs on the legacy Coordinator
    coord = Coordinator(g).start()
    try:
        coord.inject("src", 7)
        assert coord.run_until_quiescent(timeout=30)
        assert [m.payload for m in coord.drain_outputs()] == [7]
    finally:
        coord.stop()


# ---------------------------------------------------------------------------
# session lifecycle
# ---------------------------------------------------------------------------

def test_session_context_manager_teardown():
    flow = Flow("t")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x))
    with flow.session() as s:
        coord = s.coordinator
        s.inject(src, 1)
        assert s.results() == [1]
        threads = [f._thread for f in coord.flakes.values()]
        assert all(t.is_alive() for t in threads)
    # guaranteed teardown: dispatcher threads stopped, handle invalidated
    assert all(not t.is_alive() for t in threads)
    with pytest.raises(SessionStateError):
        s.coordinator


def test_session_teardown_on_exception():
    flow = Flow("t")
    flow.pellet("src", lambda: FnPellet(lambda x: x))
    with pytest.raises(RuntimeError, match="boom"):
        with flow.session() as s:
            coord = s.coordinator
            raise RuntimeError("boom")
    assert all(not f._thread.is_alive() for f in coord.flakes.values())


def test_session_drain_raises_on_timeout():
    class Stuck(PushPellet):
        def compute(self, x):
            time.sleep(1.0)
            return x

    flow = Flow("t")
    src = flow.pellet("src", Stuck)
    with flow.session(drain_timeout=0.2) as s:
        s.inject(src, 1)
        with pytest.raises(TimeoutError, match="did not quiesce"):
            s.drain()


def test_mapreduce_combinator_wordcount():
    flow = Flow("wc")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x, sequential=True))
    sink = flow.pellet("sink", lambda: FnPellet(lambda x: x))
    flow.mapreduce(
        prefix="wc",
        mapper=lambda: FnMapper(lambda line: [(w, 1) for w in line.split()]),
        reducer=lambda: FnReducer(lambda: 0, lambda a, v: a + v),
        n_mappers=2, n_reducers=3, source=src, sink=sink)
    with flow.session() as s:
        for line in ["a b a", "b c", "a c c", "d"]:
            s.inject(src, line)
        s.inject_landmark(src)
        counts = dict(p for p in s.results() if isinstance(p, tuple))
        assert counts == {"a": 3, "b": 2, "c": 3, "d": 1}
        assert not s.errors


def test_bsp_combinator_supersteps():
    def logic(wid, step, state, inbox):
        state = (state or 0) + 1
        return state, [], state >= 3

    flow = Flow("bsp")
    sink = flow.pellet("sink", lambda: FnPellet(lambda x: x))
    workers, _ = flow.bsp(prefix="bsp", n_workers=3, logic=logic, sink=sink)
    with flow.session() as s:
        s.start_bsp(workers)
        results = s.results()
        assert not s.errors
        assert results and results[0]["supersteps"] == 3


# ---------------------------------------------------------------------------
# transactional recomposition
# ---------------------------------------------------------------------------

def _three_stage_flow():
    flow = Flow("recompose")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x, sequential=True))
    sw = flow.pellet("sw", Switch)
    tag = flow.pellet("tag", lambda: Tag("v1"), cores=1)
    sink = flow.pellet("sink", lambda: FnPellet(lambda x: x))
    src >> sw
    sw["small"] >> tag
    tag >> sink
    return flow, src, sw, tag, sink


def test_recompose_swap_rewire_scale_atomically():
    """One transaction: swap a pellet + add an edge + rescale cores —
    committed together, messages in flight before/after all delivered."""
    flow, src, sw, tag, sink = _three_stage_flow()
    with flow.session() as s:
        s.inject(src, 3)                      # small -> tag(v1) -> sink
        s.inject(src, 70)                     # large -> (dropped: no route)
        out_before = s.results()
        assert ("v1", 3) in out_before
        with s.recompose() as tx:
            tx.swap(tag, lambda: Tag("v2"))
            tx.rewire(sw, sink, src_port="large", dst_port="in")
            tx.scale(tag, cores=4)
        s.inject(src, 5)                      # small -> tag(v2)
        s.inject(src, 99)                     # large -> now wired to sink
        out = [p for p in s.results() if isinstance(p, (tuple, int))]
        assert ("v2", 5) in out
        assert 99 in out
        assert s.cores(tag) == 4
        assert s.coordinator.flakes["tag"].version == 1
        assert not s.errors


def test_recompose_does_not_drop_inflight_messages():
    """Messages being processed while the transaction commits finish to
    completion and are delivered — no drops, no duplicates."""
    gate = threading.Event()

    class SlowTag(PushPellet):
        def compute(self, x):
            gate.wait(timeout=10)
            return ("slow", x)

    flow = Flow("inflight")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x, sequential=True))
    mid = flow.pellet("mid", SlowTag, cores=2)
    sink = flow.pellet("sink", lambda: FnPellet(lambda x: x))
    src >> mid
    mid >> sink
    with flow.session() as s:
        for i in range(4):
            s.inject(src, i)
        time.sleep(0.2)                      # instances now blocked in-flight

        committed = threading.Event()

        def do_tx():
            with s.recompose() as tx:
                tx.swap(mid, lambda: Tag("new"))
                tx.scale(mid, cores=3)
            committed.set()

        t = threading.Thread(target=do_tx, daemon=True)
        t.start()
        time.sleep(0.2)
        assert not committed.is_set()        # commit blocked on the drain
        gate.set()
        t.join(timeout=20)
        assert committed.is_set()
        s.inject(src, 9)
        out = [p for p in s.results() if isinstance(p, tuple)]
        # all 4 in-flight messages delivered under the OLD logic, new after
        assert sorted(p for p in out if p[0] == "slow") == \
            [("slow", i) for i in range(4)]
        assert ("new", 9) in out
        assert not s.errors


def test_recompose_validation_failure_rolls_back():
    flow, src, sw, tag, sink = _three_stage_flow()
    with flow.session() as s:
        s.inject(src, 3)
        assert ("v1", 3) in s.results()
        with pytest.raises(RecompositionError, match="no OUTPUT port"):
            with s.recompose() as tx:
                tx.swap(tag, lambda: Tag("v2"))       # valid...
                tx.scale(tag, cores=8)                # valid...
                tx.rewire(sw, sink, src_port="nope")  # ...but this is not
        # NOTHING was applied: same logic, same cores, same wiring
        s.inject(src, 4)
        assert ("v1", 4) in s.results()
        assert s.cores(tag) == 1
        assert s.coordinator.flakes["tag"].version == 0


def test_recompose_swap_port_mismatch_rolls_back():
    flow, src, sw, tag, sink = _three_stage_flow()
    with flow.session() as s:
        with pytest.raises(RecompositionError, match="port mismatch"):
            with s.recompose() as tx:
                tx.swap(tag, Switch)
        assert s.coordinator.flakes["tag"].version == 0


def test_recompose_exception_in_block_discards_staged_ops():
    flow, src, sw, tag, sink = _three_stage_flow()
    with flow.session() as s:
        with pytest.raises(KeyError):
            with s.recompose() as tx:
                tx.swap(tag, lambda: Tag("v2"))
                raise KeyError("user bug")
        s.inject(src, 3)
        assert ("v1", 3) in s.results()      # swap never applied


def test_recompose_aborts_if_drain_times_out():
    """A stage that cannot quiesce within drain_timeout aborts the whole
    transaction before any change is applied (atomicity over progress)."""
    gate = threading.Event()

    class Blocked(PushPellet):
        def compute(self, x):
            gate.wait(timeout=10)
            return ("old", x)

    flow = Flow("stuck")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x, sequential=True))
    mid = flow.pellet("mid", Blocked)
    sink = flow.pellet("sink", lambda: FnPellet(lambda x: x))
    src >> mid
    mid >> sink
    with flow.session(drain_timeout=0.3) as s:
        s.inject(src, 1)
        time.sleep(0.15)                     # message now stuck in-flight
        with pytest.raises(RecompositionError, match="did not quiesce"):
            with s.recompose() as tx:
                tx.swap(mid, lambda: Tag("new"))
                tx.scale(mid, cores=4)
        gate.set()
        # nothing was applied; the in-flight message completes as 'old'
        out = [p for p in s.results(timeout=10) if isinstance(p, tuple)]
        assert out == [("old", 1)]
        assert s.cores(mid) == 1
        assert s.coordinator.flakes["mid"].version == 0


def test_recompose_unwire_removes_edge():
    flow, src, sw, tag, sink = _three_stage_flow()
    with flow.session() as s:
        with s.recompose() as tx:
            tx.unwire(tag, sink)
        s.inject(src, 3)
        out = s.results()
        # tag now has no route: its output is collected as a sink output
        assert ("v1", 3) in out


def test_recompose_abort_sees_inline_sequential_work():
    """Sequential/pull pellets execute inline in the dispatch thread; a
    message mid-compute there must still be visible to the commit drain."""
    gate = threading.Event()

    class SeqSlow(PushPellet):
        sequential = True

        def compute(self, x):
            gate.wait(timeout=10)
            return ("old", x)

    flow = Flow("inline")
    src = flow.pellet("src", lambda: FnPellet(lambda x: x))
    mid = flow.pellet("mid", SeqSlow)
    src >> mid
    with flow.session(drain_timeout=0.3) as s:
        s.inject(src, 1)
        time.sleep(0.15)                    # mid-compute, inline
        with pytest.raises(RecompositionError, match="did not quiesce"):
            with s.recompose() as tx:
                tx.swap(mid, lambda: Tag("new"))
        gate.set()
        assert [p for p in s.results(timeout=10)] == [("old", 1)]
        assert s.coordinator.flakes["mid"].version == 0


def test_recompose_fanin_change_completes_partial_landmark_round():
    """A landmark round half-counted at a merge stage is flushed (not lost)
    when a recompose changes that stage's inbound edges."""
    from repro import WindowPellet

    class SumWin(WindowPellet):
        window = 100

        def compute(self, payloads):
            return sum(payloads)

    flow = Flow("lm")
    a = flow.pellet("a", lambda: FnPellet(lambda x: x, sequential=True))
    b = flow.pellet("b", lambda: FnPellet(lambda x: x, sequential=True))
    w = flow.pellet("w", SumWin)
    a >> w
    b >> w
    with flow.session() as s:
        s.inject(a, 1)
        s.inject(b, 2)
        time.sleep(0.2)              # both buffered in the partial window
        s.inject_landmark(a)         # 1 of 2 copies: swallowed mid-round
        time.sleep(0.2)
        with s.recompose() as tx:
            tx.unwire(b, w)          # fan-in 2 -> 1
        # the pending round was completed by the rewire: window flushed
        out = [p for p in s.results(timeout=15) if isinstance(p, int)]
        assert out == [3]
        # and alignment is clean afterwards: a fresh round flushes alone
        s.inject(a, 5)
        s.inject_landmark(a)
        out2 = [p for p in s.results(timeout=15) if isinstance(p, int)]
        assert out2 == [5]
        assert not s.errors


# ---------------------------------------------------------------------------
# declarative elasticity
# ---------------------------------------------------------------------------

def test_elastic_annotation_produces_live_scaling():
    """.elastic(...) alone — no manual AdaptationController — scales a
    loaded stage up and quiesces it back to zero when drained."""
    def work(x):
        time.sleep(0.02)
        return x

    flow = Flow("elastic")
    p = flow.pellet("p", lambda: FnPellet(work), cores=1).elastic(
        max_cores=8, strategy="dynamic", drain_horizon=1.0)
    with flow.session(sample_interval=0.1) as s:
        assert s.controller is not None      # managed automatically
        t_end = time.time() + 1.5
        while time.time() < t_end:           # offered load >> 1-core capacity
            s.inject(p, 1)
            time.sleep(0.002)
        assert s.cores(p) > 1                # scaled up live
        assert s.quiesce(timeout=60)
        for _ in range(30):
            s.controller.step_once()
        assert s.cores(p) == 0               # quiesced when idle
        st = s.stats()["p"]
        assert st["processed"] == st["arrived"]


def test_no_elastic_stages_no_controller():
    flow = Flow("t")
    flow.pellet("p", lambda: FnPellet(lambda x: x))
    with flow.session() as s:
        assert s.controller is None
