"""Streaming MapReduce+ with dynamic port mapping (paper §II.A, Fig. 1 P9)."""
import collections

from repro.core import (Coordinator, FloeGraph, FnMapper, FnPellet, FnReducer,
                        add_mapreduce)


def build_wordcount(n_mappers=2, n_reducers=3, incremental=False):
    g = FloeGraph("wc")
    g.add("src", lambda: FnPellet(lambda x: x, sequential=True))
    g.add("sink", lambda: FnPellet(lambda x: x))
    mappers, reducers = add_mapreduce(
        g, prefix="wc",
        mapper_factory=lambda: FnMapper(
            lambda line: [(w, 1) for w in line.split()]),
        reducer_factory=lambda: FnReducer(
            zero=lambda: 0, combine=lambda a, v: a + v,
            incremental=incremental),
        n_mappers=n_mappers, n_reducers=n_reducers,
        source="src", sink="sink")
    return g, mappers, reducers


def test_streaming_wordcount():
    g, _, _ = build_wordcount()
    coord = Coordinator(g).start()
    try:
        lines = ["a b a", "b c", "a c c", "d"]
        for line in lines:
            coord.inject("src", line)
        coord.inject_landmark("src")  # flush the logical window
        assert coord.run_until_quiescent(timeout=30)
        assert not coord.errors
        counts = dict(m.payload for m in coord.drain_outputs() if m.is_data()
                      and isinstance(m.payload, tuple))
        assert counts == {"a": 3, "b": 2, "c": 3, "d": 1}
    finally:
        coord.stop()


def test_shuffle_key_locality():
    """Dynamic port mapping: all values of one key land on ONE reducer."""
    g, _, reducers = build_wordcount(n_mappers=3, n_reducers=4)
    coord = Coordinator(g).start()
    try:
        for _ in range(5):
            coord.inject("src", "x y z w v u")
        coord.inject_landmark("src")
        assert coord.run_until_quiescent(timeout=30)
        # inspect reducer states were keyed disjointly: each key appears in
        # exactly one reducer's seen-set; emitted counts must be 5 per key
        out = [m.payload for m in coord.drain_outputs()
               if m.is_data() and isinstance(m.payload, tuple)]
        per_key = collections.Counter(k for k, _ in out)
        for k in "xyzwvu":
            assert per_key[k] == 1, f"key {k} flushed by >1 reducer"
        assert all(v == 5 for _, v in out)
    finally:
        coord.stop()


def test_incremental_reducer_spans_landmarks():
    """incremental=True: accumulators persist across logical windows."""
    g, _, _ = build_wordcount(n_mappers=1, n_reducers=2, incremental=True)
    coord = Coordinator(g).start()
    try:
        coord.inject("src", "a a")
        coord.inject_landmark("src")
        assert coord.run_until_quiescent(timeout=30)
        first = dict(m.payload for m in coord.drain_outputs()
                     if m.is_data() and isinstance(m.payload, tuple))
        coord.inject("src", "a")
        coord.inject_landmark("src")
        assert coord.run_until_quiescent(timeout=30)
        second = dict(m.payload for m in coord.drain_outputs()
                      if m.is_data() and isinstance(m.payload, tuple))
        assert first["a"] == 2 and second["a"] == 3
    finally:
        coord.stop()


def test_mapreduce_plus_second_reduce_stage():
    """MapReduce+: a second Reduce stage without an intermediate Map (§II.A).

    Stage 1 word-counts and *re-keys* its flushed output by count parity so
    the second hash shuffle groups by parity; stage 2 sums counts per parity.
    """
    g = FloeGraph("mr+")
    g.add("src", lambda: FnPellet(lambda x: x, sequential=True))
    g.add("sink", lambda: FnPellet(lambda x: x))
    parity = lambda k, acc: "even" if acc % 2 == 0 else "odd"
    _, reducers1 = add_mapreduce(
        g, prefix="s1",
        mapper_factory=lambda: FnMapper(
            lambda line: [(w, 1) for w in line.split()]),
        reducer_factory=lambda: FnReducer(
            lambda: 0, lambda a, v: a + v,
            finalize=lambda k, acc: (parity(k, acc), acc),
            rekey=parity),
        n_mappers=2, n_reducers=2, source="src")
    # stage 2: sum counts per parity key (no Map stage in between)
    stage2 = lambda: FnReducer(lambda: 0, lambda a, v: a + v[1])
    g.add("s2_red0", stage2)
    g.add("s2_red1", stage2)
    for r in reducers1:
        g.connect(r, "s2_red0", split="hash")
        g.connect(r, "s2_red1", split="hash")
    g.connect("s2_red0", "sink")
    g.connect("s2_red1", "sink")
    coord = Coordinator(g).start()
    try:
        coord.inject("src", "a a b b c")
        coord.inject_landmark("src")
        assert coord.run_until_quiescent(timeout=30)
        assert not coord.errors
        out = dict(m.payload for m in coord.drain_outputs()
                   if m.is_data() and isinstance(m.payload, tuple))
        # counts: a->2, b->2, c->1; parity even gets 2+2=4, odd gets 1
        assert out == {"even": 4, "odd": 1}
    finally:
        coord.stop()
