"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; "
    "install the [test] extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.adaptation.simulator import SimPellet, simulate
from repro.adaptation.strategies import (DynamicAdaptation, Observation,
                                         PelletHints, static_allocation)
from repro.core import Message
from repro.core.patterns import HashSplit, stable_hash
from repro.kernels import ops
from repro.optim.grad_compress import (compress_tree_fused, dequantize_int8,
                                       zeros_error_like)

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# dynamic port mapping invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.lists(st.one_of(st.text(max_size=8), st.integers(), st.tuples(
    st.integers(), st.text(max_size=4))), min_size=1, max_size=60),
    st.integers(min_value=1, max_value=12))
def test_hash_split_is_a_function_of_key(keys, n_edges):
    """Same key -> same edge, for any key type and edge count (§II.A)."""
    s = HashSplit()
    for key in keys:
        m1 = Message(payload="a", key=key)
        m2 = Message(payload="b", key=key)
        assert s.choose(m1, n_edges, [0] * n_edges) == \
            s.choose(m2, n_edges, [0] * n_edges)
        (e,) = s.choose(m1, n_edges, [0] * n_edges)
        assert 0 <= e < n_edges


@settings(**SETTINGS)
@given(st.integers(min_value=2, max_value=64))
def test_stable_hash_spreads(n_keys):
    edges = [stable_hash(("key", i)) % 8 for i in range(n_keys * 8)]
    counts = np.bincount(edges, minlength=8)
    assert counts.max() <= 3.5 * counts.mean()  # no catastrophic skew


# ---------------------------------------------------------------------------
# MoE routing invariants (the shuffle's correctness conditions)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=4),
       st.integers(min_value=8, max_value=64),
       st.integers(min_value=0, max_value=1000))
def test_route_invariants(e_pow, k, T, seed):
    E = 2 ** e_pow
    k = min(k, E)
    cap = max(4, T * k // E)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (T, E))
    w, e, pos, keep, src, valid = ops.route(logits, k, cap)
    w, e, pos, keep = map(np.asarray, (w, e, pos, keep))
    src, valid = np.asarray(src), np.asarray(valid)
    # weights are a distribution over the chosen experts
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)
    # kept slots are within capacity and unique per expert
    assert (pos[keep] < cap).all()
    for ex in range(E):
        taken = pos[(e == ex) & keep]
        assert len(np.unique(taken)) == len(taken)
    # valid table marks exactly the kept assignments
    assert valid.sum() == keep.sum()
    # every valid slot points at a real token row
    assert (src[valid] >= 0).all() and (src[valid] < T).all()


# ---------------------------------------------------------------------------
# adaptation invariants (§III)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.floats(min_value=0.0, max_value=500.0),
       st.integers(min_value=0, max_value=10000),
       st.floats(min_value=0.01, max_value=5.0),
       st.integers(min_value=0, max_value=32))
def test_dynamic_bounds_and_quiesce(rate, queue, latency, cores):
    d = DynamicAdaptation(max_cores=16)
    out = d.decide(Observation(0.0, queue, rate, latency, cores))
    assert 0 <= out <= 16
    if rate == 0 and queue == 0:
        assert out == 0                       # idle & drained -> quiesce


@settings(**SETTINGS)
@given(st.floats(min_value=1.0, max_value=100.0),
       st.floats(min_value=0.01, max_value=2.0))
def test_dynamic_reaches_fixed_point(rate, latency):
    """At a constant rate the controller settles (no flapping)."""
    d = DynamicAdaptation(max_cores=64)
    cores = 0
    history = []
    for _ in range(50):
        cores = d.decide(Observation(0.0, 0, rate, latency, cores))
        history.append(cores)
    assert len(set(history[-5:])) == 1        # fixed point reached
    # and the fixed point sustains the load
    cap = history[-1] * 4 / latency
    assert cap >= rate * 0.8 or history[-1] == 64


@settings(**SETTINGS)
@given(st.floats(min_value=1.0, max_value=1000.0),
       st.floats(min_value=0.001, max_value=2.0),
       st.floats(min_value=1.0, max_value=600.0))
def test_static_allocation_sustains_window(m1, latency, window):
    hints = [PelletHints(latency=latency)]
    (c,) = static_allocation(hints, m1, window, epsilon=0.0)
    # C cores = 4C instances must clear m1 messages within the window
    assert c * 4 * window / latency >= m1 * 0.999


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_simulator_conserves_messages(seed):
    rng = np.random.default_rng(seed)
    rate = float(rng.uniform(1, 30))
    p = SimPellet("p", latency=0.5)
    res = simulate([p], {"p": DynamicAdaptation(max_cores=32)},
                   lambda t: rate, horizon=120.0)
    offered = rate * 120.0
    assert p.processed_total <= offered + 1e-6
    assert abs((p.processed_total + p.queue) - offered) < rate + 1e-6


# ---------------------------------------------------------------------------
# numerics invariants
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=3),
       st.integers(min_value=2, max_value=6),
       st.integers(min_value=0, max_value=100))
def test_chunked_ce_matches_direct(b, chunks, seed):
    from repro.launch.steps import chunked_cross_entropy, cross_entropy
    S, D, V = chunks * 4, 8, 16
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (b, S, D))
    head = jax.random.normal(jax.random.PRNGKey(seed + 1), (D, V))
    labels = jax.random.randint(jax.random.PRNGKey(seed + 2), (b, S), 0, V)
    a = chunked_cross_entropy(x, head, labels, chunk=4)
    c = cross_entropy(x @ head, labels)
    np.testing.assert_allclose(float(a), float(c), rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=100))
def test_error_feedback_identity(seed):
    """EF-int8: the telescoping identity sum(dequantized) = sum(grads) -
    final_error holds exactly — compression is unbiased over time."""
    key = jax.random.PRNGKey(seed)
    grads = {"w": jax.random.normal(key, (16, 16))}
    err = zeros_error_like(grads)
    total_deq = jnp.zeros((16, 16))
    total_g = jnp.zeros((16, 16))
    for i in range(5):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (16, 16))}
        q, s, err = compress_tree_fused(g, err)
        total_deq += dequantize_int8(q["w"], s["w"])
        total_g += g["w"]
    np.testing.assert_allclose(np.asarray(total_deq + err["w"]),
                               np.asarray(total_g), atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=30),
       st.integers(min_value=0, max_value=50))
def test_ssm_scan_split_invariance(split, seed):
    """Scanning [0:split] then [split:] with the carried state equals the
    full scan — the state object is a faithful stream summary (the paper's
    stateful-pellet semantics)."""
    from repro.kernels import ref
    B, S, di, N = 1, 32, 8, 4
    split = min(split, S - 1)
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di)))
    A = -jnp.exp(jax.random.normal(ks[2], (di, N)) * 0.1)
    B_ = jax.random.normal(ks[3], (B, S, N))
    C_ = jax.random.normal(ks[4], (B, S, N))
    y_full, h_full = ref.ssm_scan(x, dt, A, B_, C_)
    y1, h1 = ref.ssm_scan(x[:, :split], dt[:, :split], A, B_[:, :split],
                          C_[:, :split])
    y2, h2 = ref.ssm_scan(x[:, split:], dt[:, split:], A, B_[:, split:],
                          C_[:, split:], h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)
