"""Transport hardening: retry/timeout/serialization edge cases, stated
directly against the transport surface (a fake destination flake) so the
policies are pinned independent of engine scheduling.

Covers the regression where ``send_timeout_s`` was silently ignored
unless a chaos injector happened to be wired in, exercised over BOTH
cross-host transports (``serializing`` and ``process``) since the
process transport inherits the whole retry/timeout/duplicate policy.
"""
import pickle
import time

import numpy as np
import pytest

from repro.core.arraybatch import ArrayBatch
from repro.core.message import Message, landmark
from repro.cluster.transport import (ProcessTransport, SerializingTransport,
                                     TransientTransportError, TransportError)

TRANSPORTS = [SerializingTransport, ProcessTransport]


class _Sink:
    """Fake destination flake: records every delivered batch."""

    name = "sink"

    def __init__(self):
        self.batches = []

    def enqueue_many(self, port, msgs):
        self.batches.append((port, list(msgs)))

    def messages(self):
        return [m for _, batch in self.batches for m in batch]


class _Injector:
    """Scripted FaultyWire stand-in: fail the first ``fail_n`` attempts
    with a transient error, optionally duplicate after success."""

    def __init__(self, fail_n=0, extra_delay_s=0.0, duplicate=False):
        self.fail_n = fail_n
        self.extra_delay_s = extra_delay_s
        self.duplicate = duplicate
        self.attempts = 0

    def before_send(self, msgs):
        self.attempts += 1
        if self.attempts <= self.fail_n:
            raise TransientTransportError("injected drop")
        return msgs, self.extra_delay_s

    def should_duplicate(self):
        return self.duplicate


# -- the send_timeout_s regression -------------------------------------------

@pytest.mark.parametrize("cls", TRANSPORTS, ids=lambda c: c.kind)
def test_send_timeout_applies_without_injector(cls):
    """A modeled delay above ``send_timeout_s`` must time out even with NO
    fault injector wired in (the timeout check used to live inside the
    injector branch, making the knob a no-op on clean wires)."""
    t = cls(per_msg_delay_s=0.5, send_timeout_s=0.05,
            max_retries=2, retry_backoff_s=0.0)
    assert t.fault_injector is None
    sink = _Sink()
    t0 = time.time()
    with pytest.raises(TransportError) as ei:
        t.deliver(sink, "in", [Message(payload=1)])
    assert not isinstance(ei.value, TransientTransportError)
    # timed out, not slept: the 0.5 s modeled delay was never paid
    assert time.time() - t0 < 0.4
    assert sink.batches == []                    # nothing delivered
    assert t.stats.timeouts == 3                 # every attempt timed out
    assert t.stats.retries == 2                  # max_retries retries burnt
    assert t.stats.messages == 0


@pytest.mark.parametrize("cls", TRANSPORTS, ids=lambda c: c.kind)
def test_send_timeout_counts_injected_delay(cls):
    """Injected extra delay participates in the timeout budget."""
    t = cls(send_timeout_s=0.05, max_retries=0, retry_backoff_s=0.0)
    t.fault_injector = _Injector(extra_delay_s=0.2)
    sink = _Sink()
    with pytest.raises(TransportError):
        t.deliver(sink, "in", [Message(payload=1)])
    assert t.stats.timeouts == 1 and sink.batches == []


# -- retry policy ------------------------------------------------------------

@pytest.mark.parametrize("cls", TRANSPORTS, ids=lambda c: c.kind)
def test_retry_exhaustion_is_permanent_error(cls):
    t = cls(max_retries=3, retry_backoff_s=0.0)
    t.fault_injector = _Injector(fail_n=10**6)   # never heals
    sink = _Sink()
    with pytest.raises(TransportError) as ei:
        t.deliver(sink, "in", [Message(payload="x")])
    assert "after 4 attempts" in str(ei.value)
    assert t.stats.retries == 3
    assert sink.batches == [] and t.stats.messages == 0


@pytest.mark.parametrize("cls", TRANSPORTS, ids=lambda c: c.kind)
def test_transient_failures_heal_within_budget(cls):
    t = cls(max_retries=3, retry_backoff_s=0.0)
    t.fault_injector = _Injector(fail_n=2)       # third attempt succeeds
    sink = _Sink()
    t.deliver(sink, "in", [Message(payload="x"), Message(payload="y")])
    assert [m.payload for m in sink.messages()] == ["x", "y"]
    assert t.stats.retries == 2 and t.stats.messages == 2


@pytest.mark.parametrize("cls", TRANSPORTS, ids=lambda c: c.kind)
def test_duplicate_delivery_counted(cls):
    t = cls()
    t.fault_injector = _Injector(duplicate=True)
    sink = _Sink()
    t.deliver(sink, "in", [Message(payload=7, seq=42)])
    msgs = sink.messages()
    assert [m.payload for m in msgs] == [7, 7]
    assert msgs[0].seq == msgs[1].seq == 42      # same logical message
    assert msgs[0] is not msgs[1]
    assert t.stats.duplicated == 1


# -- serialization enforcement ----------------------------------------------

@pytest.mark.parametrize("cls", TRANSPORTS, ids=lambda c: c.kind)
def test_non_picklable_payload_fails_at_sender(cls):
    """Serialization is enforced before anything is enqueued: a payload
    that cannot pickle delivers NOTHING (no partial batch)."""
    t = cls()
    sink = _Sink()
    bad = [Message(payload="fine"), Message(payload=lambda: 1)]
    with pytest.raises((pickle.PicklingError, TypeError, AttributeError)):
        t.deliver(sink, "in", bad)
    assert sink.batches == [] and t.stats.messages == 0


def test_serializing_breaks_reference_sharing():
    t = SerializingTransport()
    sink = _Sink()
    payload = {"a": [1, 2]}
    t.deliver(sink, "in", [Message(payload=payload)])
    (got,) = sink.messages()
    assert got.payload == payload and got.payload is not payload
    assert t.stats.bytes > 0


# -- the process transport's zero-copy carrier path --------------------------

def test_process_carrier_rides_control_channel_only():
    """An ArrayBatch carrier crossing the process wire pickles ONLY its
    seq/key/trace sidecar: the array object passes by reference (it
    crosses for real via shared memory at compute-offload time), so the
    payload-bytes ledger stays at zero."""
    t = ProcessTransport()
    sink = _Sink()
    arr = np.arange(4096.0).reshape(64, 64)
    ab = ArrayBatch(arr, seqs=list(range(64)), keys=None)
    t.deliver(sink, "in", [Message(payload=ab)])
    (got,) = sink.messages()
    assert isinstance(got.payload, ArrayBatch)
    assert got.payload.array is arr              # no array copy, no pickle
    assert got.payload.seqs == list(range(64))
    assert got.payload is not ab                 # sidecars round-tripped
    assert t.stats.bytes == 0
    assert t.stats.control_bytes > 0
    assert t.stats.messages == 1


def test_process_control_messages_counted_as_control():
    t = ProcessTransport()
    sink = _Sink()
    t.deliver(sink, "in", [landmark("flush")])
    assert t.stats.bytes == 0 and t.stats.control_bytes > 0
    assert sink.messages()[0].landmark


def test_process_data_rows_still_serialized():
    """Plain (non-carrier) payloads on the process wire round-trip through
    pickle exactly like the serializing transport — counted as ``bytes``."""
    t = ProcessTransport()
    sink = _Sink()
    payload = {"k": 3}
    t.deliver(sink, "in", [Message(payload=payload)])
    (got,) = sink.messages()
    assert got.payload == payload and got.payload is not payload
    assert t.stats.bytes > 0
